//! Versioned JSON wire protocol for the coordinator: newline-delimited
//! request/response records, the serialization behind
//! `repro serve --requests <file.jsonl|->`.
//!
//! Every record carries the protocol version (`"v": 1`). A request names a
//! workload either out of the catalog or as a full inline
//! [`WorkloadSpec`] — both content-address to the same compiled artifact
//! when structurally identical (see [`super::cache::WorkloadKey`]):
//!
//! ```json
//! {"v":1,"id":1,"workload":{"name":"gemm","n":8},"target":"tcpa","batch":2,"validate":true,"seed":3}
//! {"v":1,"id":2,"workload":{"spec":{...}},"target":"cgra"}
//! ```
//!
//! `id` is a client-assigned correlation token echoed in the response;
//! under a multi-worker pool responses arrive in *completion* order, so the
//! echo (plus `n`/`batch`) is what keeps them attributable. `batch`
//! defaults to 1, `validate` to false, `seed` to 0.
//!
//! A response mirrors the request's correlation fields and adds the
//! execution report:
//!
//! ```json
//! {"v":1,"id":1,"workload":"gemm","n":8,"target":"tcpa","batch":2,
//!  "latency_cycles":1234,"batch_cycles":1300,"validated":true,
//!  "cache_hit":false,"exec_cache_hit":false,"symbolic_hit":false,
//!  "error":null,"wall_us":842}
//! ```
//!
//! `exec_cache_hit` reports whether the whole execution report was served
//! from the coordinator's exec cache (a byte-identical repeat request);
//! `symbolic_hit` whether the artifact was instantiated from an already
//! resident per-shape symbolic compile (a fresh size of a known kernel
//! shape). Both default to `false` when absent so records written by older
//! builds still parse.
//!
//! Malformed request lines do not abort the stream: they produce an error
//! record `{"v":1,"line":<lineno>,"error":"..."}` and serving continues.

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Duration;

use crate::backend::Target;
use crate::bench::spec::{WorkloadCatalog, WorkloadSpec};
use crate::util::json::{opt_u64, req_i64, req_str, req_u64, Json};

use super::metrics::Metrics;
use super::pool;
use super::pool::PoolConfig;
use super::session::{ErrorKind, Redundancy, Request, Response, WorkloadRef};

/// Wire protocol version; bump when any record shape changes.
pub const WIRE_VERSION: i64 = 1;

/// Largest batch a wire request may ask for. Batch cycle accounting is
/// closed-form u64 arithmetic (`single * batch`, `last + (B-1)*first`), so
/// an unbounded client value would overflow it; 2^20 back-to-back
/// invocations is far beyond any meaningful sweep.
pub const MAX_BATCH: u64 = 1 << 20;

// ============================ requests ======================================

/// Encode a request as a wire record.
pub fn request_to_json(r: &Request) -> Json {
    let workload = match &r.workload {
        WorkloadRef::Named { name, n } => Json::obj(vec![
            ("name", Json::from(name.clone())),
            ("n", Json::Int(*n)),
        ]),
        WorkloadRef::Inline(spec) => Json::obj(vec![("spec", spec.to_json())]),
    };
    let mut fields = vec![
        ("v", Json::Int(WIRE_VERSION)),
        ("id", Json::Int(r.id as i64)),
        ("workload", workload),
        ("target", Json::from(r.target.name())),
        ("batch", Json::Int(r.batch as i64)),
        ("validate", Json::Bool(r.validate)),
        ("seed", Json::Int(r.seed as i64)),
    ];
    // additive resilience fields: emitted only when set, so records stay
    // byte-identical with pre-resilience builds otherwise
    if let Some(ms) = r.deadline_ms {
        fields.push(("deadline_ms", Json::Int(ms as i64)));
    }
    if r.allow_fallback {
        fields.push(("allow_fallback", Json::Bool(true)));
    }
    if r.redundancy != Redundancy::None {
        fields.push(("redundancy", Json::from(r.redundancy.name())));
    }
    Json::obj(fields)
}

/// Decode a wire record into a request.
pub fn request_from_json(j: &Json) -> Result<Request, String> {
    check_version(j)?;
    let workload = j.get("workload").ok_or("missing field `workload`")?;
    let workload = if let Some(spec) = workload.get("spec") {
        WorkloadRef::Inline(WorkloadSpec::from_json(spec)?)
    } else if let Some(name) = workload.get("name") {
        WorkloadRef::Named {
            name: name
                .as_str()
                .ok_or("workload name must be a string")?
                .to_string(),
            n: workload
                .get("n")
                .and_then(Json::as_i64)
                .ok_or("named workload needs an integer `n`")?,
        }
    } else {
        return Err("workload must carry `name`+`n` or an inline `spec`".into());
    };
    let target_s = j
        .get("target")
        .and_then(Json::as_str)
        .ok_or("missing field `target`")?;
    let target = Target::parse(target_s).ok_or_else(|| {
        format!(
            "unknown target `{target_s}` (want one of: {})",
            Target::ALL.map(|t| t.name()).join(", ")
        )
    })?;
    let batch = opt_u64(j, "batch", 1)?;
    if batch == 0 {
        // reject rather than silently coerce: the response echoes `batch`,
        // so a rewritten value would break client correlation
        return Err("field `batch` must be at least 1".into());
    }
    if batch > MAX_BATCH {
        return Err(format!("field `batch` exceeds the maximum of {MAX_BATCH}"));
    }
    let deadline_ms = match j.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_i64()
                .filter(|ms| *ms >= 0)
                .ok_or("field `deadline_ms` must be a non-negative integer")? as u64,
        ),
    };
    Ok(Request {
        id: opt_u64(j, "id", 0)?,
        workload,
        target,
        batch,
        validate: match j.get("validate") {
            None | Some(Json::Null) => false,
            Some(v) => v.as_bool().ok_or("field `validate` must be a boolean")?,
        },
        seed: opt_u64(j, "seed", 0)?,
        deadline_ms,
        allow_fallback: match j.get("allow_fallback") {
            None | Some(Json::Null) => false,
            Some(v) => v
                .as_bool()
                .ok_or("field `allow_fallback` must be a boolean")?,
        },
        redundancy: match j.get("redundancy") {
            None | Some(Json::Null) => Redundancy::None,
            Some(v) => {
                let s = v.as_str().ok_or("field `redundancy` must be a string")?;
                Redundancy::parse(s)
                    .ok_or_else(|| format!("unknown redundancy `{s}` (want none, dmr or tmr)"))?
            }
        },
    })
}

/// Parse one JSONL request line.
pub fn parse_request_line(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    request_from_json(&j)
}

// ============================ responses =====================================

/// Encode a response as a wire record.
pub fn response_to_json(r: &Response) -> Json {
    let mut fields = vec![
        ("v", Json::Int(WIRE_VERSION)),
        ("id", Json::Int(r.id as i64)),
        ("workload", Json::from(r.workload.clone())),
        ("n", Json::Int(r.n)),
        ("target", Json::from(r.target.name())),
        ("batch", Json::Int(r.batch as i64)),
        ("latency_cycles", Json::Int(r.latency_cycles as i64)),
        ("batch_cycles", Json::Int(r.batch_cycles as i64)),
        (
            "validated",
            r.validated.map(Json::Bool).unwrap_or(Json::Null),
        ),
        ("cache_hit", Json::Bool(r.cache_hit)),
        ("exec_cache_hit", Json::Bool(r.exec_cache_hit)),
        ("symbolic_hit", Json::Bool(r.symbolic_hit)),
        ("degraded", Json::Bool(r.degraded)),
        ("retries", Json::Int(r.retries as i64)),
        (
            "error",
            r.error
                .clone()
                .map(Json::from)
                .unwrap_or(Json::Null),
        ),
        ("wall_us", Json::Int(r.wall.as_micros() as i64)),
    ];
    if let Some(k) = r.error_kind {
        fields.push(("error_kind", Json::from(k.name())));
    }
    // additive fault-plane fields: emitted only when set, so healthy
    // records stay byte-identical with pre-fault builds (protocol stays v1)
    if r.fault_detected {
        fields.push(("fault_detected", Json::Bool(true)));
    }
    if r.remapped {
        fields.push(("remapped", Json::Bool(true)));
    }
    if r.corrected {
        fields.push(("corrected", Json::Bool(true)));
    }
    Json::obj(fields)
}

/// Decode a wire record into a response (what a JSONL client does).
pub fn response_from_json(j: &Json) -> Result<Response, String> {
    check_version(j)?;
    let target_s = req_str(j, "target")?;
    let error = match j.get("error") {
        None | Some(Json::Null) => None,
        Some(e) => Some(
            e.as_str()
                .ok_or("field `error` must be a string")?
                .to_string(),
        ),
    };
    Ok(Response {
        id: req_u64(j, "id")?,
        workload: req_str(j, "workload")?,
        n: req_i64(j, "n")?,
        target: Target::parse(&target_s)
            .ok_or_else(|| format!("unknown target `{target_s}`"))?,
        batch: req_u64(j, "batch")?,
        latency_cycles: req_u64(j, "latency_cycles")?,
        batch_cycles: req_u64(j, "batch_cycles")?,
        validated: match j.get("validated") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_bool().ok_or("field `validated` must be a boolean")?),
        },
        cache_hit: j
            .get("cache_hit")
            .and_then(Json::as_bool)
            .ok_or("missing field `cache_hit`")?,
        // absent in pre-exec-cache records: default to "not a replay"
        exec_cache_hit: j
            .get("exec_cache_hit")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        // absent in pre-symbolic records: default to "not instantiated"
        symbolic_hit: j
            .get("symbolic_hit")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        // absent in pre-resilience records: default to the primary path
        degraded: j.get("degraded").and_then(Json::as_bool).unwrap_or(false),
        retries: opt_u64(j, "retries", 0)?,
        error_kind: match j.get("error_kind") {
            None | Some(Json::Null) => {
                // older records carry no kind; any error they report was a
                // plain failure (shed/timeout records did not exist yet)
                error.as_ref().map(|_| ErrorKind::Failed)
            }
            Some(v) => {
                let s = v.as_str().ok_or("field `error_kind` must be a string")?;
                Some(ErrorKind::parse(s).ok_or_else(|| format!("unknown error_kind `{s}`"))?)
            }
        },
        error,
        // absent in pre-fault records: default to "no fault event"
        fault_detected: j
            .get("fault_detected")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        remapped: j.get("remapped").and_then(Json::as_bool).unwrap_or(false),
        corrected: j.get("corrected").and_then(Json::as_bool).unwrap_or(false),
        wall: Duration::from_micros(req_u64(j, "wall_us")?),
    })
}

fn check_version(j: &Json) -> Result<(), String> {
    match j.get("v").and_then(Json::as_i64) {
        Some(WIRE_VERSION) => Ok(()),
        Some(v) => Err(format!(
            "unsupported wire version {v} (this build speaks {WIRE_VERSION})"
        )),
        None => Err("missing field `v` (wire version)".into()),
    }
}

/// The error record emitted for an unparseable request line. When the
/// malformed line still parsed far enough to recover a request `id` (see
/// [`recover_request_id`]), the record echoes it, so socket clients can
/// correlate failures without counting lines.
pub fn line_error_json(lineno: usize, msg: &str, id: Option<u64>) -> Json {
    let mut fields = vec![
        ("v", Json::Int(WIRE_VERSION)),
        ("line", Json::from(lineno)),
    ];
    if let Some(id) = id {
        fields.push(("id", Json::Int(id as i64)));
    }
    fields.push(("error", Json::from(msg)));
    Json::obj(fields)
}

/// Best-effort request-id recovery from a line that failed
/// [`parse_request_line`]: if the line is syntactically valid JSON with a
/// non-negative integer `id`, return it — whatever else is wrong with the
/// request (bad version, unknown target, invalid workload).
pub fn recover_request_id(line: &str) -> Option<u64> {
    let j = Json::parse(line).ok()?;
    let id = j.get("id")?.as_i64()?;
    u64::try_from(id).ok()
}

// ============================ JSONL serving =================================

/// Serve newline-delimited JSON requests from `input` through an
/// `n_workers` pool over `catalog`, writing one JSON response line per
/// request in *completion* order (the echoed `id` correlates them).
///
/// Fully streaming: each request is dispatched to the pool as soon as its
/// line parses, and a writer thread emits responses as they complete — so
/// an interactive client on stdin sees its first answer before closing the
/// pipe, and a huge request file never buffers in memory. Malformed lines
/// produce error records (interleaved with responses, carrying their line
/// number) and do not abort the stream. Returns the pool's merged metrics.
pub fn serve_jsonl(
    input: &mut dyn BufRead,
    out: &mut (dyn Write + Send),
    n_workers: usize,
    catalog: Arc<WorkloadCatalog>,
) -> std::io::Result<Metrics> {
    serve_jsonl_configured(input, out, n_workers, catalog, PoolConfig::default())
}

/// [`serve_jsonl`] under an explicit [`PoolConfig`]: the JSONL front end of
/// the resilience plane (bounded queue, default deadline). Shed and expired
/// requests still emit one response record each.
pub fn serve_jsonl_configured(
    input: &mut dyn BufRead,
    out: &mut (dyn Write + Send),
    n_workers: usize,
    catalog: Arc<WorkloadCatalog>,
    config: PoolConfig,
) -> std::io::Result<Metrics> {
    serve_jsonl_sharded(input, out, n_workers, 1, catalog, config)
}

/// [`serve_jsonl_configured`] over `n_shards` fresh cache shards (see
/// [`super::shard::CacheShards`]): the file/stdin front end of the same
/// sharded plane the socket server runs on.
pub fn serve_jsonl_sharded(
    input: &mut dyn BufRead,
    out: &mut (dyn Write + Send),
    n_workers: usize,
    n_shards: usize,
    catalog: Arc<WorkloadCatalog>,
    config: PoolConfig,
) -> std::io::Result<Metrics> {
    let (tx, rx, handle) = pool::serve_sharded(
        n_workers,
        Arc::new(super::shard::CacheShards::new(n_shards)),
        catalog,
        config,
    );
    let out = std::sync::Mutex::new(out);
    std::thread::scope(|s| -> std::io::Result<()> {
        // writer: stream responses in completion order until the pool drains
        let out_ref = &out;
        let writer = s.spawn(move || -> std::io::Result<()> {
            for resp in rx.iter() {
                // a poisoned lock only means the other side panicked while
                // writing; the stream itself is still usable
                let mut o = out_ref
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                writeln!(o, "{}", response_to_json(&resp).render())?;
            }
            Ok(())
        });
        // reader: dispatch each request the moment its line parses. Errors
        // break out instead of early-returning: the queue MUST close before
        // the scope joins the writer, or both would wait forever.
        let mut read_result: std::io::Result<()> = Ok(());
        for (i, line) in input.lines().enumerate() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    read_result = Err(e);
                    break;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            match parse_request_line(&line) {
                Ok(req) => {
                    // send fails only when every worker died; the writer
                    // side will have surfaced that
                    let _ = tx.send(req);
                }
                Err(e) => {
                    let mut o = out
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    let record =
                        line_error_json(i + 1, &e, recover_request_id(&line)).render();
                    if let Err(io_err) = writeln!(o, "{record}") {
                        read_result = Err(io_err);
                        break;
                    }
                }
            }
        }
        drop(tx);
        let write_result = match writer.join() {
            Ok(r) => r,
            Err(_) => Err(std::io::Error::other("wire writer thread panicked")),
        };
        read_result.and(write_result)
    })?;
    Ok(handle.join())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_request_roundtrips() {
        let req = Request::named(7, "gemm", 8, Target::Tcpa, 2, true, 3);
        let j = request_to_json(&req);
        let back = request_from_json(&j).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.workload.name(), "gemm");
        assert_eq!(back.workload.n(), 8);
        assert_eq!(back.target, Target::Tcpa);
        assert_eq!((back.batch, back.validate, back.seed), (2, true, 3));
    }

    #[test]
    fn defaults_apply_to_omitted_fields() {
        let req = parse_request_line(
            r#"{"v":1,"workload":{"name":"atax","n":8},"target":"seq"}"#,
        )
        .unwrap();
        assert_eq!(req.id, 0);
        assert_eq!(req.batch, 1);
        assert!(!req.validate);
        assert_eq!(req.seed, 0);
    }

    #[test]
    fn bad_requests_are_rejected_with_reasons() {
        for (line, needle) in [
            (r#"{"workload":{"name":"gemm","n":8},"target":"tcpa"}"#, "wire version"),
            (
                r#"{"v":2,"workload":{"name":"gemm","n":8},"target":"tcpa"}"#,
                "unsupported wire version",
            ),
            (r#"{"v":1,"workload":{"name":"gemm","n":8}}"#, "target"),
            (r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"gpu"}"#, "unknown target"),
            (r#"{"v":1,"workload":{},"target":"tcpa"}"#, "name"),
            (r#"{"v":1,"workload":{"name":"gemm"},"target":"tcpa"}"#, "`n`"),
            (
                r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa","batch":0}"#,
                "`batch` must be at least 1",
            ),
            (
                r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa","batch":9999999999}"#,
                "`batch` exceeds",
            ),
            (r#"not json"#, "JSON error"),
        ] {
            let e = parse_request_line(line).unwrap_err();
            assert!(e.contains(needle), "{line} -> {e}");
        }
    }

    #[test]
    fn response_roundtrips_including_error_and_null_fields() {
        let resp = Response {
            id: 42,
            workload: "jacobi2d".into(),
            n: 10,
            target: Target::Cgra,
            batch: 3,
            latency_cycles: 100,
            batch_cycles: 300,
            validated: None,
            cache_hit: true,
            exec_cache_hit: true,
            symbolic_hit: true,
            degraded: false,
            error: Some("boom".into()),
            error_kind: Some(ErrorKind::Failed),
            retries: 0,
            fault_detected: false,
            remapped: false,
            corrected: false,
            wall: Duration::from_micros(555),
        };
        let back = response_from_json(&response_to_json(&resp)).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.workload, "jacobi2d");
        assert_eq!(back.validated, None);
        assert!(back.exec_cache_hit);
        assert!(back.symbolic_hit);
        assert_eq!(back.error.as_deref(), Some("boom"));
        assert_eq!(back.error_kind, Some(ErrorKind::Failed));
        assert_eq!(back.wall, Duration::from_micros(555));

        let ok = Response {
            validated: Some(true),
            error: None,
            error_kind: None,
            ..resp
        };
        let back = response_from_json(&response_to_json(&ok)).unwrap();
        assert_eq!(back.validated, Some(true));
        assert_eq!(back.error, None);
        assert_eq!(back.error_kind, None, "no kind is fabricated for success");
    }

    #[test]
    fn responses_without_exec_cache_hit_still_parse() {
        // a pre-exec-cache v1 record (no `exec_cache_hit`/`symbolic_hit`)
        let line = r#"{"v":1,"id":1,"workload":"gemm","n":8,"target":"tcpa","batch":1,"latency_cycles":10,"batch_cycles":10,"validated":null,"cache_hit":false,"error":null,"wall_us":5}"#;
        let r = response_from_json(&Json::parse(line).unwrap()).unwrap();
        assert!(!r.exec_cache_hit, "absent field defaults to false");
        assert!(!r.symbolic_hit, "absent field defaults to false");
    }

    #[test]
    fn resilience_request_fields_roundtrip_and_default() {
        let req = Request::named(9, "gemm", 8, Target::Cgra, 1, false, 0)
            .with_deadline_ms(250)
            .with_fallback();
        let back = request_from_json(&request_to_json(&req)).unwrap();
        assert_eq!(back.deadline_ms, Some(250));
        assert!(back.allow_fallback);
        // absent fields keep the pre-resilience meaning
        let plain = parse_request_line(
            r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa"}"#,
        )
        .unwrap();
        assert_eq!(plain.deadline_ms, None);
        assert!(!plain.allow_fallback);
        // ...and a bare record encodes without the new keys at all
        let bare = request_to_json(&Request::named(1, "gemm", 8, Target::Tcpa, 1, false, 0));
        assert!(bare.get("deadline_ms").is_none());
        assert!(bare.get("allow_fallback").is_none());
        let e = parse_request_line(
            r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa","deadline_ms":-5}"#,
        )
        .unwrap_err();
        assert!(e.contains("`deadline_ms`"), "{e}");
    }

    #[test]
    fn resilience_response_fields_roundtrip_and_default() {
        let shed = Response {
            id: 1,
            workload: "gemm".into(),
            n: 8,
            target: Target::Tcpa,
            batch: 1,
            latency_cycles: 0,
            batch_cycles: 0,
            validated: None,
            cache_hit: false,
            exec_cache_hit: false,
            symbolic_hit: false,
            degraded: false,
            error: Some("request shed: queue at capacity 4".into()),
            error_kind: Some(ErrorKind::Shed),
            retries: 2,
            fault_detected: false,
            remapped: false,
            corrected: false,
            wall: Duration::ZERO,
        };
        let back = response_from_json(&response_to_json(&shed)).unwrap();
        assert_eq!(back.error_kind, Some(ErrorKind::Shed));
        assert_eq!(back.retries, 2);
        // a degraded success roundtrips its mark
        let degraded = Response {
            degraded: true,
            error: None,
            error_kind: None,
            retries: 0,
            ..shed
        };
        let back = response_from_json(&response_to_json(&degraded)).unwrap();
        assert!(back.degraded);
        assert_eq!(back.error_kind, None);
        // a pre-resilience error record parses as a plain failure
        let line = r#"{"v":1,"id":1,"workload":"gemm","n":8,"target":"tcpa","batch":1,"latency_cycles":0,"batch_cycles":0,"validated":null,"cache_hit":false,"error":"boom","wall_us":5}"#;
        let old = response_from_json(&Json::parse(line).unwrap()).unwrap();
        assert!(!old.degraded);
        assert_eq!(old.retries, 0);
        assert_eq!(old.error_kind, Some(ErrorKind::Failed));
        // unknown kinds are rejected, not coerced
        let bad = r#"{"v":1,"id":1,"workload":"gemm","n":8,"target":"tcpa","batch":1,"latency_cycles":0,"batch_cycles":0,"validated":null,"cache_hit":false,"error":"x","error_kind":"dropped","wall_us":5}"#;
        let e = response_from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(e.contains("unknown error_kind"), "{e}");
    }

    #[test]
    fn redundancy_roundtrips_and_defaults_to_none() {
        for r in [Redundancy::Dmr, Redundancy::Tmr] {
            let req = Request::named(3, "gemm", 8, Target::Cgra, 1, false, 0)
                .with_redundancy(r);
            let back = request_from_json(&request_to_json(&req)).unwrap();
            assert_eq!(back.redundancy, r, "{}", r.name());
        }
        // absent field keeps the pre-fault meaning; plain requests encode
        // without the key at all
        let plain = parse_request_line(
            r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa"}"#,
        )
        .unwrap();
        assert_eq!(plain.redundancy, Redundancy::None);
        let bare = request_to_json(&Request::named(1, "gemm", 8, Target::Tcpa, 1, false, 0));
        assert!(bare.get("redundancy").is_none());
        // unknown modes are rejected, not coerced
        let e = parse_request_line(
            r#"{"v":1,"workload":{"name":"gemm","n":8},"target":"tcpa","redundancy":"quad"}"#,
        )
        .unwrap_err();
        assert!(e.contains("unknown redundancy"), "{e}");
    }

    #[test]
    fn fault_response_fields_roundtrip_and_default() {
        let healthy = Response {
            id: 1,
            workload: "gemm".into(),
            n: 8,
            target: Target::Tcpa,
            batch: 1,
            latency_cycles: 10,
            batch_cycles: 10,
            validated: Some(true),
            cache_hit: false,
            exec_cache_hit: false,
            symbolic_hit: false,
            degraded: false,
            error: None,
            error_kind: None,
            retries: 0,
            fault_detected: false,
            remapped: false,
            corrected: false,
            wall: Duration::from_micros(5),
        };
        // healthy records carry none of the fault keys — byte-compatible
        // with pre-fault readers
        let j = response_to_json(&healthy);
        assert!(j.get("fault_detected").is_none());
        assert!(j.get("remapped").is_none());
        assert!(j.get("corrected").is_none());
        // a remapped-and-served response roundtrips all three flags
        let faulted = Response {
            fault_detected: true,
            remapped: true,
            corrected: true,
            ..healthy.clone()
        };
        let back = response_from_json(&response_to_json(&faulted)).unwrap();
        assert!(back.fault_detected && back.remapped && back.corrected);
        // a pre-fault record parses with the flags off
        let line = r#"{"v":1,"id":1,"workload":"gemm","n":8,"target":"tcpa","batch":1,"latency_cycles":10,"batch_cycles":10,"validated":null,"cache_hit":false,"error":null,"wall_us":5}"#;
        let old = response_from_json(&Json::parse(line).unwrap()).unwrap();
        assert!(!old.fault_detected && !old.remapped && !old.corrected);
        // the Fault kind survives the wire like every other kind
        let fault = Response {
            error: Some("[vote-mismatch] no TMR majority (request 1)".into()),
            error_kind: Some(ErrorKind::Fault),
            ..healthy
        };
        let back = response_from_json(&response_to_json(&fault)).unwrap();
        assert_eq!(back.error_kind, Some(ErrorKind::Fault));
    }

    #[test]
    fn every_error_kind_roundtrips_the_wire() {
        // table-driven over the full enum: adding a kind without a wire
        // name (or a parse arm) fails here, not in production
        for kind in ErrorKind::ALL {
            let resp = Response::failure(
                &Request::named(1, "gemm", 8, Target::Tcpa, 1, false, 0),
                format!("synthetic {} error", kind.name()),
                kind,
                false,
                false,
                false,
                Duration::from_micros(7),
            );
            let back = response_from_json(&response_to_json(&resp)).unwrap();
            assert_eq!(back.error_kind, Some(kind), "{}", kind.name());
            assert_eq!(back.error, resp.error);
        }
    }

    #[test]
    fn line_errors_identify_the_line() {
        let j = line_error_json(3, "boom", None);
        assert_eq!(j.get("line").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("v").unwrap().as_i64(), Some(WIRE_VERSION));
        assert!(j.get("id").is_none(), "no id recovered, none echoed");
        let j = line_error_json(3, "boom", Some(42));
        assert_eq!(j.get("id").unwrap().as_i64(), Some(42));
    }

    #[test]
    fn error_records_echo_a_recoverable_id() {
        // valid JSON, bad request (unknown target): id is recoverable
        assert_eq!(
            recover_request_id(r#"{"v":1,"id":17,"target":"warp"}"#),
            Some(17)
        );
        // syntactically broken line: nothing to recover
        assert_eq!(recover_request_id("not json at all"), None);
        // negative ids are not coerced
        assert_eq!(recover_request_id(r#"{"id":-4}"#), None);
        // end to end: the error record for a bad-but-parseable line
        // carries the id, the record for garbage does not
        let input = format!(
            "{}\n{}\n",
            r#"{"v":99,"id":17,"workload":{"name":"gemm","n":8},"target":"tcpa"}"#,
            "garbage"
        );
        let mut out = Vec::new();
        serve_jsonl(
            &mut input.as_bytes(),
            &mut out,
            1,
            Arc::new(WorkloadCatalog::builtin()),
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let mut with_id = 0;
        let mut without_id = 0;
        for l in text.lines() {
            let j = Json::parse(l).unwrap();
            assert!(j.get("line").is_some(), "both records are line errors: {l}");
            match j.get("id").and_then(Json::as_i64) {
                Some(17) => with_id += 1,
                Some(other) => panic!("unexpected id {other} in {l}"),
                None => without_id += 1,
            }
        }
        assert_eq!((with_id, without_id), (1, 1), "{text}");
    }
}
