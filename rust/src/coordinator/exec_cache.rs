//! Shared execution-report cache: the steady-state serve path.
//!
//! A byte-identical repeat request — same content-addressed workload, same
//! problem size, same target, same input seed, same batch — deterministically
//! produces the same [`ExecReport`]: input generation is a pure function of
//! `(spec, seed)`, the compiled artifact is immutable, and both simulators
//! are cycle-deterministic. So the coordinator memoizes whole reports behind
//! `Arc<ExecReport>` keyed by [`ExecKey`] and serves repeats with **zero
//! plan lowering, zero input regeneration and zero simulation** — the
//! TCPA-side discipline (pay at compile time, replay cheaply per invocation)
//! applied one level up, to the serving plane itself.
//!
//! The cache rides on the same [`FlightMap`] as the compile cache
//! ([`super::cache`]): single-flight (N workers racing on a cold key run
//! the pipeline once; the rest park and share the leader's report),
//! size-bounded LRU eviction (client-controlled key space must not grow
//! server memory without bound; in-flight executions are never evicted),
//! and cached failures (execution errors — timing violations, missing
//! pipelined latency — are as deterministic as the reports). Like the
//! compile cache, *transient* results (a panicked leader, a deadline abort)
//! resolve poisoned-once: waiters still receive the error, the slot is
//! dropped, and the next request retries fresh.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::backend::ExecReport;

use super::cache::{
    is_transient_error, CacheOutcome, FlightMap, WorkloadKey, MAX_POISON_RETRIES, PANIC_MARKER,
};

/// Default bound on resident execution reports per process. Each entry
/// holds one invocation's output arrays (bounded by the spec validator's
/// input/iteration caps), so the bound is what keeps a hostile stream of
/// distinct `(seed, batch)` values from growing server memory.
pub const DEFAULT_EXEC_CAPACITY: usize = 1024;

/// Key of one memoized execution: the compiled artifact's content address
/// plus everything else `execute` depends on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecKey {
    /// Which compiled artifact ran (spec fingerprint + size + target).
    pub workload: WorkloadKey,
    /// Input-generation seed.
    pub seed: u64,
    /// Batch size (batch semantics are the backend's, but the resulting
    /// cycle accounting differs per batch, so it is part of the key).
    pub batch: u64,
}

impl std::fmt::Display for ExecKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/s{}/b{}", self.workload, self.seed, self.batch)
    }
}

/// What one cached execution resolves to: a shared report, or the
/// deterministic error the pipeline produced.
pub type ExecResult = Result<Arc<ExecReport>, String>;

/// Atomic counters exposed to metrics and the eviction tests.
#[derive(Debug, Default)]
pub struct ExecCacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub waits: AtomicU64,
    /// Actual pipeline executions — mirrors the compile cache's
    /// `compiles == misses` identity.
    pub execs: AtomicU64,
    /// Ready entries dropped by the LRU bound.
    pub evictions: AtomicU64,
    /// Flights resolved poisoned-once (leader panicked or hit its
    /// deadline): the result reached its waiters but was never cached.
    pub poisoned: AtomicU64,
}

impl ExecCacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn execs(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn poisoned(&self) -> u64 {
        self.poisoned.load(Ordering::Relaxed)
    }
}

/// The process-wide execution-report cache (see module docs).
pub struct ExecCache {
    slots: FlightMap<ExecKey, ExecResult>,
    pub stats: ExecCacheStats,
}

impl ExecCache {
    /// A cache at the default capacity.
    pub fn new() -> ExecCache {
        ExecCache::with_capacity(DEFAULT_EXEC_CAPACITY)
    }

    /// A cache holding at most `capacity` ready reports (in-flight
    /// executions ride on top of the bound and are never evicted).
    pub fn with_capacity(capacity: usize) -> ExecCache {
        ExecCache {
            slots: FlightMap::new(capacity),
            stats: ExecCacheStats::default(),
        }
    }

    /// Most ready reports the cache will keep resident.
    pub fn capacity(&self) -> usize {
        self.slots.capacity()
    }

    /// Number of resident entries (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Drop every *ready* report produced on `target` (in-flight executions
    /// finish and resolve to their waiters, but a detected hardware fault
    /// means their reports may be corrupt, so nothing already resident for
    /// that array may be served again). Returns the number dropped.
    pub fn invalidate_target(&self, target: crate::backend::Target) -> usize {
        self.slots.drop_ready(|k| k.workload.target == target)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the memoized report for `key`, running `exec` (the full
    /// compile-lookup → input-gen → execute pipeline) at most once across
    /// all threads per resident key. `exec` runs with no cache lock held,
    /// so it may itself block on the compile cache's single flight.
    pub fn get_or_run(
        &self,
        key: ExecKey,
        exec: impl FnOnce() -> Result<ExecReport, String>,
    ) -> (ExecResult, CacheOutcome) {
        self.get_or_run_tracked(key, exec, &std::cell::Cell::new(0))
    }

    /// [`ExecCache::get_or_run`] with bounded secondhand retry: a caller
    /// that *waited* on a flight and received a transient result (the
    /// leader panicked or aborted on *its* deadline — the poisoned slot is
    /// already gone) retries up to [`MAX_POISON_RETRIES`] times with a
    /// short backoff. Each retry increments `retries`. The `exec` closure
    /// is consumed by the first attempt that leads; retried attempts can
    /// only lead if the prior attempt waited, so it is never run twice.
    pub fn get_or_run_tracked(
        &self,
        key: ExecKey,
        exec: impl FnOnce() -> Result<ExecReport, String>,
        retries: &std::cell::Cell<u64>,
    ) -> (ExecResult, CacheOutcome) {
        let mut run = Some(exec);
        let mut attempt = 0u32;
        loop {
            let (result, outcome) = self.slots.get_or_run(
                key,
                || (run.take().expect("exec closure led at most once"))().map(Arc::new),
                |msg| Err(format!("{PANIC_MARKER} execution pipeline panicked: {msg}")),
                |r| r.as_ref().err().is_some_and(|e| is_transient_error(e)),
                &self.stats.evictions,
                &self.stats.poisoned,
            );
            match outcome {
                CacheOutcome::Hit => self.stats.hits.fetch_add(1, Ordering::Relaxed),
                CacheOutcome::Waited => self.stats.waits.fetch_add(1, Ordering::Relaxed),
                CacheOutcome::Miss => {
                    self.stats.execs.fetch_add(1, Ordering::Relaxed);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed)
                }
            };
            let secondhand_transient = outcome == CacheOutcome::Waited
                && result.as_ref().err().is_some_and(|e| is_transient_error(e));
            if secondhand_transient && attempt < MAX_POISON_RETRIES {
                attempt += 1;
                retries.set(retries.get() + 1);
                std::thread::sleep(std::time::Duration::from_micros(50 << attempt));
                continue;
            }
            return (result, outcome);
        }
    }
}

impl Default for ExecCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Target;
    use crate::ir::loopnest::ArrayData;

    fn key(fp: u64, seed: u64, batch: u64) -> ExecKey {
        ExecKey {
            workload: WorkloadKey {
                fingerprint: fp,
                n: 8,
                target: Target::Seq,
            },
            seed,
            batch,
        }
    }

    fn report(latency: u64) -> ExecReport {
        ExecReport {
            latency_cycles: latency,
            batch_cycles: latency,
            issued_ops: latency,
            occupancy: 1.0,
            outputs: ArrayData::new(),
            detail: "test".into(),
            seu_flips: 0,
        }
    }

    #[test]
    fn memoizes_reports_and_shares_the_arc() {
        let cache = ExecCache::new();
        let (r1, o1) = cache.get_or_run(key(1, 0, 1), || Ok(report(7)));
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2) = cache.get_or_run(key(1, 0, 1), || panic!("must not re-execute"));
        assert_eq!(o2, CacheOutcome::Hit);
        assert!(Arc::ptr_eq(&r1.unwrap(), &r2.unwrap()), "shared report");
        assert_eq!(cache.stats.execs(), 1);
    }

    #[test]
    fn seed_and_batch_are_part_of_the_key() {
        let cache = ExecCache::new();
        cache.get_or_run(key(1, 0, 1), || Ok(report(1)));
        let (_, o_seed) = cache.get_or_run(key(1, 9, 1), || Ok(report(2)));
        let (_, o_batch) = cache.get_or_run(key(1, 0, 4), || Ok(report(3)));
        assert_eq!(o_seed, CacheOutcome::Miss);
        assert_eq!(o_batch, CacheOutcome::Miss);
        assert_eq!(cache.stats.execs(), 3);
        assert!(key(1, 9, 1).to_string().ends_with("/s9/b1"));
    }

    #[test]
    fn errors_are_cached_like_reports() {
        let cache = ExecCache::new();
        let (r1, _) = cache.get_or_run(key(2, 0, 1), || Err("boom".into()));
        assert_eq!(r1.unwrap_err(), "boom");
        let (r2, o2) = cache.get_or_run(key(2, 0, 1), || panic!("must not retry"));
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(r2.unwrap_err(), "boom");
    }

    #[test]
    fn panics_poison_once_and_the_next_request_retries_fresh() {
        let cache = ExecCache::new();
        let (r, o) = cache.get_or_run(key(3, 0, 1), || panic!("kaboom"));
        assert_eq!(o, CacheOutcome::Miss);
        let msg = r.unwrap_err();
        assert!(msg.contains("kaboom"), "{msg}");
        assert!(is_transient_error(&msg), "panic results carry the marker");
        assert_eq!(cache.stats.poisoned(), 1);
        assert_eq!(cache.len(), 0, "the poisoned slot is not resident");
        // poison never sticks: the same key re-executes and succeeds
        let (r2, o2) = cache.get_or_run(key(3, 0, 1), || Ok(report(1)));
        assert_eq!(o2, CacheOutcome::Miss, "fresh flight, not a cached panic");
        assert!(r2.is_ok());
        // …and from here on it is an ordinary resident report
        let (_, o3) = cache.get_or_run(key(3, 0, 1), || panic!("must not rerun"));
        assert_eq!(o3, CacheOutcome::Hit);
        assert_eq!(cache.stats.execs(), cache.stats.misses());
    }

    #[test]
    fn lru_bound_holds_and_misses_match_execs() {
        let cache = ExecCache::with_capacity(2);
        for fp in 0..6 {
            cache.get_or_run(key(fp, 0, 1), || Ok(report(fp)));
            assert!(cache.len() <= 2);
        }
        assert_eq!(cache.stats.evictions(), 4);
        let (_, o) = cache.get_or_run(key(0, 0, 1), || Ok(report(0)));
        assert_eq!(o, CacheOutcome::Miss, "evicted entries re-execute");
        assert_eq!(cache.stats.execs(), cache.stats.misses());
    }
}
