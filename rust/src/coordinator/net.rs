//! Socket front-end for the serving plane: TCP and Unix-domain listeners
//! speaking wire protocol v1 (JSONL framing) to many concurrent clients.
//!
//! The transport is deliberately thin — a listener, one reader and one
//! writer per connection, and nothing else. Every accepted line flows
//! through the *existing* [`PoolSender`] admission edge via
//! [`PoolSender::send_routed`], so shedding, deadline stamping and
//! degradation behave byte-for-byte like the file/stdin path
//! (`wire::serve_jsonl_*`): same records, same counters, same identities.
//! One connection is one client stream — responses are written back on the
//! connection they arrived on, in completion order, with the echoed `id`
//! for correlation (exactly the JSONL contract).
//!
//! Connection lifecycle:
//!
//! * **accept** — the listener thread accepts, bumps `conns_accepted`, and
//!   spawns a connection thread;
//! * **serve** — the connection's reader parses lines and admits them with
//!   a per-connection reply channel + abort flag; a writer thread drains
//!   the reply channel onto the socket. Malformed lines emit
//!   [`wire::line_error_json`] records (with the recovered `id` when the
//!   line parsed that far) interleaved with responses;
//! * **hangup** — if a write fails (peer gone) or a read errors, the abort
//!   flag is raised: every request the connection still has in flight
//!   cancels at its next [`crate::backend::CancelToken`] checkpoint with a
//!   `[cancelled]`-tagged timeout instead of burning worker time, and the
//!   connection counts as `conns_aborted`;
//! * **drain** — on clean end-of-stream the reader drops its reply sender,
//!   the writer drains the in-flight tail, and the connection counts as
//!   `conns_closed`.
//!
//! Shutdown joins everything and folds the connection counters into the
//! pool's merged [`Metrics`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use crate::bench::spec::WorkloadCatalog;

use super::metrics::Metrics;
use super::pool::{self, PoolConfig, PoolHandle, PoolSender};
use super::session::Response;
use super::shard::CacheShards;
use super::wire;

/// Where to listen: a TCP socket address or a Unix-domain socket path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    Tcp(String),
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse a CLI listen spec. Explicit schemes win: `tcp:HOST:PORT` and
    /// `unix:PATH`. Without a scheme, anything containing `/` is a
    /// filesystem path; everything else is a TCP address.
    pub fn parse(spec: &str) -> ListenAddr {
        if let Some(path) = spec.strip_prefix("unix:") {
            ListenAddr::Unix(PathBuf::from(path))
        } else if let Some(addr) = spec.strip_prefix("tcp:") {
            ListenAddr::Tcp(addr.to_string())
        } else if spec.contains('/') {
            ListenAddr::Unix(PathBuf::from(spec))
        } else {
            ListenAddr::Tcp(spec.to_string())
        }
    }
}

impl std::fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ListenAddr::Tcp(a) => write!(f, "tcp:{a}"),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One stream, either family. Both halves come from `try_clone`, so the
/// reader and writer own independent handles onto the same socket.
enum NetStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl NetStream {
    fn try_clone(&self) -> std::io::Result<NetStream> {
        Ok(match self {
            NetStream::Tcp(s) => NetStream::Tcp(s.try_clone()?),
            NetStream::Unix(s) => NetStream::Unix(s.try_clone()?),
        })
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Unix(s) => s.flush(),
        }
    }
}

enum NetListener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl NetListener {
    fn accept(&self) -> std::io::Result<NetStream> {
        Ok(match self {
            NetListener::Tcp(l) => NetStream::Tcp(l.accept()?.0),
            NetListener::Unix(l) => NetStream::Unix(l.accept()?.0),
        })
    }
}

/// Connection counters shared by the listener and every connection thread.
/// `aborted` is bumped the moment a hangup is detected (not at connection
/// teardown), so tests and monitors can observe mid-flight disconnects.
#[derive(Default)]
pub struct ConnCounters {
    pub accepted: AtomicU64,
    pub closed: AtomicU64,
    pub aborted: AtomicU64,
}

/// Raise a connection's abort flag exactly once, counting the abort on the
/// first raise (whichever side — reader or writer — notices first).
fn raise_abort(abort: &AtomicBool, counters: &ConnCounters) {
    if !abort.swap(true, Ordering::SeqCst) {
        counters.aborted.fetch_add(1, Ordering::SeqCst);
    }
}

/// A running socket server. Dropping it does *not* stop the listener; call
/// [`NetServer::shutdown`] (tests) or [`NetServer::run`] (CLI).
pub struct NetServer {
    /// Where the server actually listens — for TCP this resolves `:0` to
    /// the kernel-assigned port, so loopback tests can connect.
    local: ListenAddr,
    stop: Arc<AtomicBool>,
    counters: Arc<ConnCounters>,
    accept_thread: thread::JoinHandle<()>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    sender: PoolSender,
    pool: PoolHandle,
}

/// Start a socket server over an explicit shard set: bind `addr`, spawn
/// `n_workers` pool workers over `shards`, and serve until
/// [`NetServer::shutdown`]. A stale Unix socket file at the path is
/// replaced.
pub fn serve(
    addr: &ListenAddr,
    n_workers: usize,
    shards: Arc<CacheShards>,
    catalog: Arc<WorkloadCatalog>,
    config: PoolConfig,
) -> std::io::Result<NetServer> {
    let (listener, local) = match addr {
        ListenAddr::Tcp(a) => {
            let l = TcpListener::bind(a.as_str())?;
            let bound: SocketAddr = l.local_addr()?;
            (NetListener::Tcp(l), ListenAddr::Tcp(bound.to_string()))
        }
        ListenAddr::Unix(p) => {
            // a dead server's socket file blocks bind; replacing it is the
            // conventional unix-socket serve idiom
            let _ = std::fs::remove_file(p);
            (NetListener::Unix(UnixListener::bind(p)?), addr.clone())
        }
    };
    let (sender, pool_rx, pool) = pool::serve_sharded(n_workers, shards, catalog, config);
    // the shared response channel is unused — every request is routed to
    // its connection's reply channel — but workers hold clones of its
    // sender, so dropping the receiver here is safe and costs nothing
    drop(pool_rx);

    let stop = Arc::new(AtomicBool::new(false));
    let counters = Arc::new(ConnCounters::default());
    let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

    let accept_thread = {
        let stop = stop.clone();
        let counters = counters.clone();
        let conns = conns.clone();
        let sender = sender.clone();
        thread::spawn(move || loop {
            let stream = listener.accept();
            if stop.load(Ordering::SeqCst) {
                break; // the shutdown poke, or anything racing it
            }
            match stream {
                Ok(s) => {
                    counters.accepted.fetch_add(1, Ordering::SeqCst);
                    let sender = sender.clone();
                    let counters = counters.clone();
                    let handle = thread::spawn(move || serve_connection(s, sender, counters));
                    // a poisoned registry only means another accept iteration
                    // panicked mid-push; the handle list itself is intact
                    conns
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .push(handle);
                }
                Err(_) => {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // transient accept failure (EMFILE, aborted handshake):
                    // keep listening
                }
            }
        })
    };

    Ok(NetServer {
        local,
        stop,
        counters,
        accept_thread,
        conns,
        sender,
        pool,
    })
}

/// Start a socket server with `n_shards` fresh default shards — the CLI
/// entry point behind `repro serve --listen … --shards S`.
pub fn serve_default(
    addr: &ListenAddr,
    n_workers: usize,
    n_shards: usize,
    config: PoolConfig,
) -> std::io::Result<NetServer> {
    serve(
        addr,
        n_workers,
        Arc::new(CacheShards::new(n_shards)),
        Arc::new(WorkloadCatalog::builtin()),
        config,
    )
}

impl NetServer {
    /// The bound address — with TCP port 0 this is the real port.
    pub fn local_addr(&self) -> &ListenAddr {
        &self.local
    }

    /// Live connection counters (accepted / closed / aborted; active is
    /// `accepted - closed - aborted`).
    pub fn counters(&self) -> &Arc<ConnCounters> {
        &self.counters
    }

    /// Block serving until the listener dies (the CLI foreground mode).
    pub fn run(self) -> Metrics {
        let _ = self.accept_thread.join();
        NetServer::drain(self.local, self.counters, self.conns, self.sender, self.pool)
    }

    /// Stop accepting, drain every connection and the pool, and return the
    /// merged metrics with connection counters folded in.
    pub fn shutdown(self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept() the listener thread is parked in
        match &self.local {
            ListenAddr::Tcp(a) => {
                let _ = TcpStream::connect(a.as_str());
            }
            ListenAddr::Unix(p) => {
                let _ = UnixStream::connect(p);
            }
        }
        let _ = self.accept_thread.join();
        NetServer::drain(self.local, self.counters, self.conns, self.sender, self.pool)
    }

    fn drain(
        local: ListenAddr,
        counters: Arc<ConnCounters>,
        conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
        sender: PoolSender,
        pool: PoolHandle,
    ) -> Metrics {
        let handles: Vec<_> = std::mem::take(
            &mut *conns
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner),
        );
        for h in handles {
            let _ = h.join();
        }
        // every connection's reply sender is gone; closing the admission
        // edge lets the workers drain and exit
        drop(sender);
        let mut m = pool.join();
        m.conns_accepted += counters.accepted.load(Ordering::SeqCst);
        m.conns_closed += counters.closed.load(Ordering::SeqCst);
        m.conns_aborted += counters.aborted.load(Ordering::SeqCst);
        if let ListenAddr::Unix(p) = &local {
            let _ = std::fs::remove_file(p);
        }
        m
    }
}

/// Serve one connection: reader parses and admits, writer streams
/// responses back, hangup raises the shared abort flag.
fn serve_connection(stream: NetStream, sender: PoolSender, counters: Arc<ConnCounters>) {
    let write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => {
            counters.aborted.fetch_add(1, Ordering::SeqCst);
            return;
        }
    };
    // raised exactly once per connection, by whichever side notices first
    let abort = Arc::new(AtomicBool::new(false));
    let out = Arc::new(Mutex::new(write_half));
    let (reply_tx, reply_rx) = mpsc::channel::<Response>();

    let writer = {
        let out = out.clone();
        let abort = abort.clone();
        let counters = counters.clone();
        thread::spawn(move || {
            // keep draining after a hangup so in-flight workers' sends keep
            // succeeding cheaply; their responses are discarded
            for resp in reply_rx.iter() {
                if abort.load(Ordering::SeqCst) {
                    continue;
                }
                let line = wire::response_to_json(&resp).render();
                if !write_line(&out, &line) {
                    raise_abort(&abort, &counters);
                }
            }
        })
    };

    let reader = BufReader::new(stream);
    for (i, line) in reader.lines().enumerate() {
        if abort.load(Ordering::SeqCst) {
            // peer already gone; stop parsing its backlog
            break;
        }
        let line = match line {
            Ok(l) => l,
            Err(_) => {
                raise_abort(&abort, &counters);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match wire::parse_request_line(&line) {
            Ok(req) => {
                // admission answers (shed/expired) and worker responses all
                // arrive on this connection's reply channel; Err means the
                // pool itself is gone, so the stream is over
                if sender
                    .send_routed(req, reply_tx.clone(), abort.clone())
                    .is_err()
                {
                    break;
                }
            }
            Err(e) => {
                let record =
                    wire::line_error_json(i + 1, &e, wire::recover_request_id(&line)).render();
                if !write_line(&out, &record) {
                    raise_abort(&abort, &counters);
                    break;
                }
            }
        }
    }
    // end of stream: drop our reply sender so the writer drains the tail
    // (workers still hold clones for in-flight requests) and exits
    drop(reply_tx);
    let _ = writer.join();
    if !abort.load(Ordering::SeqCst) {
        counters.closed.fetch_add(1, Ordering::SeqCst);
    }
}

/// Write one JSONL record under the connection's write lock. Returns false
/// on any I/O error (the caller raises the abort flag).
fn write_line(out: &Mutex<NetStream>, line: &str) -> bool {
    let mut o = out.lock().unwrap_or_else(|p| p.into_inner());
    o.write_all(line.as_bytes())
        .and_then(|_| o.write_all(b"\n"))
        .and_then(|_| o.flush())
        .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_specs_parse() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7070"),
            ListenAddr::Tcp("127.0.0.1:7070".into())
        );
        assert_eq!(
            ListenAddr::parse("tcp:0.0.0.0:9"),
            ListenAddr::Tcp("0.0.0.0:9".into())
        );
        assert_eq!(
            ListenAddr::parse("/tmp/repro.sock"),
            ListenAddr::Unix(PathBuf::from("/tmp/repro.sock"))
        );
        assert_eq!(
            ListenAddr::parse("unix:relative.sock"),
            ListenAddr::Unix(PathBuf::from("relative.sock"))
        );
        assert_eq!(
            ListenAddr::parse("./local.sock"),
            ListenAddr::Unix(PathBuf::from("./local.sock"))
        );
        assert_eq!(ListenAddr::parse("localhost:80").to_string(), "tcp:localhost:80");
    }
}
