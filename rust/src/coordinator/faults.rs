//! Deterministic fault injection for the resilience chaos suite.
//!
//! A [`FaultPlan`] is a *seeded, pure* decision function: whether a fault
//! fires at a given site for a given request id is a hash of
//! `(seed, site, id)` — no RNG state, no ordering dependence — so a chaos
//! test replaying the same trace against the same plan injects exactly the
//! same faults regardless of worker interleaving, and a failure reproduces
//! from its seed alone.
//!
//! The module (and the hooks that consult it in [`super::session`] and
//! [`super::pool`]) is compiled only under
//! `#[cfg(any(test, feature = "fault-injection"))]`: production builds
//! carry no injection branches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A named injection site in the serving plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic inside the compile half of the exec closure — exercises the
    /// compile/exec single-flight panic quarantine.
    CompilePanic,
    /// Sleep [`FaultPlan::delay`] before compiling — makes deadlines
    /// observable at stage boundaries.
    CompileDelay,
    /// Panic after compile, before the simulator runs — poisons the exec
    /// flight with a partially-executed request.
    ExecPanic,
    /// Sleep [`FaultPlan::delay`] in the worker loop between dequeue and
    /// handling — backs the queue up so admission control engages.
    QueueStall,
    /// A PE reports fail-stop during execution — exercises the hardware
    /// fault plane: quarantine, cache invalidation, spare-aware remap.
    PeFailStop,
}

impl FaultSite {
    pub const ALL: [FaultSite; 5] = [
        FaultSite::CompilePanic,
        FaultSite::CompileDelay,
        FaultSite::ExecPanic,
        FaultSite::QueueStall,
        FaultSite::PeFailStop,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultSite::CompilePanic => "compile_panic",
            FaultSite::CompileDelay => "compile_delay",
            FaultSite::ExecPanic => "exec_panic",
            FaultSite::QueueStall => "queue_stall",
            FaultSite::PeFailStop => "pe_fail_stop",
        }
    }

    fn index(&self) -> usize {
        match self {
            FaultSite::CompilePanic => 0,
            FaultSite::CompileDelay => 1,
            FaultSite::ExecPanic => 2,
            FaultSite::QueueStall => 3,
            FaultSite::PeFailStop => 4,
        }
    }
}

/// A seeded injection schedule: per-site firing rates in per-mille of
/// requests, one shared delay for the stall sites, and per-site counters of
/// faults actually injected (what the chaos suite reconciles against).
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rates: [u16; 5],
    delay: Duration,
    injected: [AtomicU64; 5],
}

impl FaultPlan {
    /// An empty plan (no site fires) under `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            ..FaultPlan::default()
        }
    }

    /// Fire `site` for `per_mille`‰ of request ids (0 = never, 1000 =
    /// every request).
    pub fn with_rate(mut self, site: FaultSite, per_mille: u16) -> FaultPlan {
        self.rates[site.index()] = per_mille.min(1000);
        self
    }

    /// Duration the delay sites sleep when they fire.
    pub fn with_delay(mut self, delay: Duration) -> FaultPlan {
        self.delay = delay;
        self
    }

    pub fn delay(&self) -> Duration {
        self.delay
    }

    /// Decide (purely, from `(seed, site, request id)`) whether `site`
    /// fires for this request, counting fires in [`FaultPlan::injected`].
    pub fn should_fire(&self, site: FaultSite, request_id: u64) -> bool {
        let rate = self.rates[site.index()];
        if rate == 0 {
            return false;
        }
        let fire = self.decision_hash(site, request_id) % 1000 < rate as u64;
        if fire {
            self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// The FNV-1a hash of the decision tuple `(seed, site, request id)` —
    /// pure and side-effect free. [`FaultPlan::should_fire`] thresholds it;
    /// sites that need extra deterministic entropy (which PE fails, say)
    /// derive it from the same hash so a replayed trace picks the same
    /// victim.
    pub fn decision_hash(&self, site: FaultSite, request_id: u64) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self
            .seed
            .to_le_bytes()
            .into_iter()
            .chain([site.index() as u8])
            .chain(request_id.to_le_bytes())
        {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// How many times `site` has actually fired.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_in_seed_site_and_id() {
        let a = FaultPlan::new(7).with_rate(FaultSite::CompilePanic, 500);
        let b = FaultPlan::new(7).with_rate(FaultSite::CompilePanic, 500);
        for id in 0..64 {
            assert_eq!(
                a.should_fire(FaultSite::CompilePanic, id),
                b.should_fire(FaultSite::CompilePanic, id),
                "id={id}"
            );
        }
        assert_eq!(
            a.injected(FaultSite::CompilePanic),
            b.injected(FaultSite::CompilePanic)
        );
    }

    #[test]
    fn rates_bound_the_firing_fraction() {
        let never = FaultPlan::new(1).with_rate(FaultSite::ExecPanic, 0);
        let always = FaultPlan::new(1).with_rate(FaultSite::ExecPanic, 1000);
        let half = FaultPlan::new(1).with_rate(FaultSite::ExecPanic, 500);
        let mut fired = 0;
        for id in 0..1000u64 {
            assert!(!never.should_fire(FaultSite::ExecPanic, id));
            assert!(always.should_fire(FaultSite::ExecPanic, id));
            if half.should_fire(FaultSite::ExecPanic, id) {
                fired += 1;
            }
        }
        assert!(
            (300..=700).contains(&fired),
            "500‰ should fire roughly half the time, got {fired}/1000"
        );
        assert_eq!(half.injected(FaultSite::ExecPanic), fired);
    }

    #[test]
    fn sites_decide_independently() {
        let plan = FaultPlan::new(3)
            .with_rate(FaultSite::CompilePanic, 1000)
            .with_rate(FaultSite::QueueStall, 0);
        assert!(plan.should_fire(FaultSite::CompilePanic, 5));
        assert!(!plan.should_fire(FaultSite::QueueStall, 5));
        assert_eq!(plan.injected(FaultSite::CompilePanic), 1);
        assert_eq!(plan.injected(FaultSite::QueueStall), 0);
        for site in FaultSite::ALL {
            assert!(!site.name().is_empty());
        }
    }

    #[test]
    fn decision_hash_is_pure_and_replays_the_victim() {
        let plan = FaultPlan::new(9).with_rate(FaultSite::PeFailStop, 1000);
        let h1 = plan.decision_hash(FaultSite::PeFailStop, 42);
        let h2 = plan.decision_hash(FaultSite::PeFailStop, 42);
        assert_eq!(h1, h2, "hash is pure");
        assert_eq!(plan.injected(FaultSite::PeFailStop), 0, "hash never counts");
        assert!(plan.should_fire(FaultSite::PeFailStop, 42));
        assert_eq!(plan.injected(FaultSite::PeFailStop), 1);
        // a victim derived from the hash replays across plans with one seed
        let replay = FaultPlan::new(9).with_rate(FaultSite::PeFailStop, 1000);
        assert_eq!(h1 >> 32, replay.decision_hash(FaultSite::PeFailStop, 42) >> 32);
    }
}
