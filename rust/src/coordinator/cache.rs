//! Shared, thread-safe compile cache with single-flight semantics and a
//! size-bounded LRU eviction policy, keyed by *content address*.
//!
//! The map/schedule pipeline ([`crate::backend::Backend::compile`] over the
//! registered backends) dominates request latency, so its results are cached
//! behind an `Arc<RwLock<HashMap>>` keyed by [`WorkloadKey`] — a stable
//! FNV-1a fingerprint of the [`WorkloadSpec`] plus problem size and target —
//! and shared by every worker of a [`super::pool`]. Content addressing means
//! an *inline* user-submitted spec that is structurally identical to a
//! catalog entry (or to another client's submission) dedupes onto the same
//! artifact: the cache never needs to know where a spec came from.
//!
//! When N workers race on the same cold key, exactly one runs the pipeline
//! (the *leader*); the rest park on a condvar and receive the leader's
//! result — each distinct kernel is compiled once per process, which is what
//! amortizes compile time across invocations (the §V-A batching argument at
//! service scale).
//!
//! The key space is client-controlled (the open workload API accepts
//! arbitrary specs), so the cache is *bounded*: beyond
//! [`CompileCache::capacity`] resident artifacts the least-recently-used
//! ready entry is evicted (in-flight compiles are never evicted — waiters
//! hold their flight handle and the leader always publishes its result).
//! An evicted key simply recompiles on its next request, still
//! single-flight, and every eviction is counted in [`CacheStats`].
//!
//! The cache is target-agnostic: it stores `Arc<dyn Mapped>` and resolves
//! the pipeline through its [`BackendRegistry`], so a new backend plugs in
//! by registration alone — no cache change, no new enum variant.
//!
//! *Deterministic* compile failures are cached too: the pipeline is
//! deterministic, so a failing (spec, target) would fail identically on
//! every retry. *Transient* results are not — a panicked leader or a
//! deadline abort says nothing about the next request's fate, so those
//! flights resolve **poisoned-once**: waiters still receive the error (never
//! a hang), but the slot is removed instead of cached and the next request
//! retries fresh. Callers that observed a poisoned flight secondhand (a
//! `Waited` outcome carrying a transient error) may retry with bounded
//! backoff via [`CompileCache::get_or_compile_shaped_cancellable`].
//!
//! The single-flight + LRU machinery itself is the generic [`FlightMap`],
//! shared with the execution-report cache
//! ([`super::exec_cache::ExecCache`]) so both caches follow exactly the
//! same discipline.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::backend::{BackendRegistry, CancelToken, Mapped, SymbolicMapped, Target};
use crate::bench::spec::WorkloadSpec;

/// Marker every panic-quarantine error message carries, so error
/// classification (the session's `error_kind`, the degradation guard, the
/// poison-retry policy) survives message nesting the same way
/// [`crate::backend::DEADLINE_MARKER`] does.
pub(crate) const PANIC_MARKER: &str = "[panic]";

/// Bound on secondhand retries after observing a poisoned flight: a waiter
/// that received a transient error it did not cause retries at most this
/// many times before reporting the error as-is.
pub(crate) const MAX_POISON_RETRIES: u32 = 2;

/// Whether an error message records a *transient* outcome (a panicked
/// leader, a deadline abort, a client-gone abort, or a detected hardware
/// fault) rather than a deterministic pipeline failure. Transient results
/// are never cached and are eligible for secondhand retry; deterministic
/// failures cache forever. Fail-stop detections are transient by
/// definition: the session quarantines the PE and recompiles under the new
/// mask, so the error says nothing about the *remapped* artifact's fate.
pub fn is_transient_error(msg: &str) -> bool {
    msg.contains(PANIC_MARKER)
        || msg.contains(crate::faults::PE_FAULT_MARKER)
        || crate::backend::is_deadline_error(msg)
        || crate::backend::is_cancel_error(msg)
}

/// Default bound on resident compiled artifacts per process.
pub const DEFAULT_COMPILE_CAPACITY: usize = 512;

/// Default bound on resident *symbolic* (per-shape) artifacts. The shape
/// population is O(distinct kernels), not O(distinct sizes), so a small
/// bound suffices.
pub const DEFAULT_SYMBOLIC_CAPACITY: usize = 128;

/// Content-addressed cache key: one compiled artifact per (spec fingerprint,
/// size, target). The size rides along for observability — it is already
/// folded into the fingerprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadKey {
    /// [`WorkloadSpec::fingerprint`] — FNV-1a over the spec's canonical JSON.
    pub fingerprint: u64,
    /// Problem size the spec was built at.
    pub n: i64,
    pub target: Target,
}

impl WorkloadKey {
    /// The key a spec compiles under for a target.
    pub fn of(spec: &WorkloadSpec, target: Target) -> WorkloadKey {
        WorkloadKey {
            fingerprint: spec.fingerprint(),
            n: spec.n,
            target,
        }
    }
}

impl std::fmt::Display for WorkloadKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:016x}/n{}/{}",
            self.fingerprint,
            self.n,
            self.target.name()
        )
    }
}

/// Key of the symbolic (per-shape) cache level: one size-independent
/// artifact per ([`WorkloadSpec::shape_fingerprint`], target). Every problem
/// size of the same kernel resolves to the same shape key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// [`WorkloadSpec::shape_fingerprint`] — FNV-1a over the spec's
    /// canonical JSON with sizes replaced by symbolic offsets from `n`.
    pub shape: u64,
    pub target: Target,
}

/// How a request's compile was served with respect to the symbolic level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SymbolicUse {
    /// The per-n path ran (backend declined a symbolic compile, the spec
    /// was ineligible, or the per-n artifact was already cached).
    None,
    /// The artifact came from instantiating a symbolic compile; `reused`
    /// is true when the shape artifact was already resident (or in flight)
    /// rather than built by this request.
    Instantiated { reused: bool },
}

/// What a single-flight cache lookup observed for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Result was already cached.
    Hit,
    /// This caller ran the pipeline.
    Miss,
    /// Another caller was running it; this one waited for its result.
    Waited,
}

// ===================== generic single-flight LRU map ========================

/// Rendezvous for callers that arrive while the leader is computing.
struct Flight<V> {
    done: Mutex<Option<V>>,
    cv: Condvar,
}

enum Slot<V> {
    InFlight(Arc<Flight<V>>),
    Ready(V),
}

/// One resident entry: the slot plus its LRU stamp (atomic so the shared
/// read lock on the fast path can still refresh recency).
struct Entry<V> {
    slot: Slot<V>,
    stamp: AtomicU64,
}

/// What a caller holds after consulting the slot map.
enum Claim<V> {
    Ready(V),
    Join(Arc<Flight<V>>),
    Lead(Arc<Flight<V>>),
}

/// A bounded, single-flight memo map: `get_or_run` computes each key at
/// most once across all threads, parks concurrent callers on the leader's
/// flight, and evicts the least-recently-used *ready* entry beyond
/// `capacity` (in-flight entries are never evicted, so the resident count
/// may transiently exceed the bound by the number of concurrent leaders).
///
/// Lock discipline: reads (the steady state) take the RwLock in shared
/// mode; the write lock is held only to flip slot states and evict, never
/// across the computation itself.
pub(super) struct FlightMap<K, V> {
    slots: RwLock<HashMap<K, Entry<V>>>,
    capacity: usize,
    tick: AtomicU64,
}

impl<K: Eq + Hash + Clone, V: Clone> FlightMap<K, V> {
    pub fn new(capacity: usize) -> FlightMap<K, V> {
        assert!(capacity >= 1, "a cache needs room for at least one entry");
        FlightMap {
            slots: RwLock::new(HashMap::new()),
            capacity,
            tick: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of resident entries (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    fn stamp(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// Fetch or compute the value for `key`, running `run` at most once
    /// across all threads per resident key. A panic inside `run` is caught
    /// and converted through `on_panic` so waiters (and all future callers)
    /// still resolve. Evictions increment `evictions`.
    ///
    /// Results for which `transient` holds resolve **poisoned-once**: the
    /// flight is still published (waiters receive the value, never a hang)
    /// and `poisoned` is incremented, but the slot is *removed* instead of
    /// cached — the next `get_or_run` for the key starts a fresh flight.
    pub fn get_or_run(
        &self,
        key: K,
        run: impl FnOnce() -> V,
        on_panic: impl FnOnce(String) -> V,
        transient: impl FnOnce(&V) -> bool,
        evictions: &AtomicU64,
        poisoned: &AtomicU64,
    ) -> (V, CacheOutcome) {
        // fast path: shared read lock
        let seen = {
            let slots = self.slots.read().unwrap();
            self.claim_of(slots.get(&key))
        };
        let claim = match seen {
            Some(c) => c,
            None => {
                // slow path: claim or join the flight under the write lock
                let mut slots = self.slots.write().unwrap();
                match self.claim_of(slots.get(&key)) {
                    Some(c) => c,
                    None => {
                        let flight = Arc::new(Flight {
                            done: Mutex::new(None),
                            cv: Condvar::new(),
                        });
                        slots.insert(
                            key.clone(),
                            Entry {
                                slot: Slot::InFlight(flight.clone()),
                                stamp: AtomicU64::new(self.stamp()),
                            },
                        );
                        Self::evict(&mut slots, self.capacity, evictions);
                        Claim::Lead(flight)
                    }
                }
            }
        };

        match claim {
            Claim::Ready(v) => (v, CacheOutcome::Hit),
            Claim::Join(flight) => {
                let mut done = flight.done.lock().unwrap();
                while done.is_none() {
                    done = flight.cv.wait(done).unwrap();
                }
                (done.as_ref().unwrap().clone(), CacheOutcome::Waited)
            }
            Claim::Lead(flight) => {
                // leader: compute with no lock held; a panic inside must
                // still resolve the flight, or every waiter (and all future
                // requests for this key) would hang forever
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run))
                    .unwrap_or_else(|p| on_panic(panic_message(&p)));
                {
                    let mut slots = self.slots.write().unwrap();
                    if transient(&result) {
                        // poisoned-once: publish to the waiters below but
                        // drop the slot, so the next request retries fresh
                        // instead of replaying a panic or a deadline abort
                        slots.remove(&key);
                        poisoned.fetch_add(1, Ordering::Relaxed);
                    } else {
                        slots.insert(
                            key,
                            Entry {
                                slot: Slot::Ready(result.clone()),
                                stamp: AtomicU64::new(self.stamp()),
                            },
                        );
                        Self::evict(&mut slots, self.capacity, evictions);
                    }
                }
                {
                    let mut done = flight.done.lock().unwrap();
                    *done = Some(result.clone());
                }
                flight.cv.notify_all();
                (result, CacheOutcome::Miss)
            }
        }
    }

    /// Drop every *ready* entry whose key matches `pred`, returning how
    /// many were dropped. In-flight entries are left alone — their waiters
    /// hold the flight handle and the leader publishes on resolution; the
    /// caller's predicate will simply not cover keys inserted afterwards.
    /// This is the health-event invalidation hook: a detected hardware
    /// fault makes every resident artifact/report for that array suspect.
    pub fn drop_ready(&self, pred: impl Fn(&K) -> bool) -> usize {
        let mut slots = self.slots.write().unwrap();
        let victims: Vec<K> = slots
            .iter()
            .filter(|(k, e)| matches!(e.slot, Slot::Ready(_)) && pred(k))
            .map(|(k, _)| k.clone())
            .collect();
        let dropped = victims.len();
        for k in victims {
            slots.remove(&k);
        }
        dropped
    }

    /// Interpret a slot lookup, refreshing the LRU stamp on a hit.
    fn claim_of(&self, entry: Option<&Entry<V>>) -> Option<Claim<V>> {
        entry.map(|e| match &e.slot {
            Slot::Ready(v) => {
                e.stamp.store(self.stamp(), Ordering::Relaxed);
                Claim::Ready(v.clone())
            }
            Slot::InFlight(f) => Claim::Join(f.clone()),
        })
    }

    /// Drop least-recently-used ready entries once the map outgrows the
    /// capacity. In-flight entries are skipped: their waiters hold the
    /// flight handle, and the leader will re-insert on resolution anyway.
    ///
    /// Eviction is *batched with hysteresis*: one sorted scan brings the
    /// map down to `capacity − capacity/8`, so a miss-heavy stream of
    /// distinct keys pays one O(n log n) scan per batch of inserts instead
    /// of a full-map scan under the write lock on every insert. (For
    /// capacities below 8 the slack is zero and eviction degenerates to
    /// exact LRU, which is what the bound tests exercise.)
    fn evict(slots: &mut HashMap<K, Entry<V>>, capacity: usize, evictions: &AtomicU64) {
        if slots.len() <= capacity {
            return;
        }
        let target = capacity - capacity / 8;
        let mut ready: Vec<(u64, K)> = slots
            .iter()
            .filter(|(_, e)| matches!(e.slot, Slot::Ready(_)))
            .map(|(k, e)| (e.stamp.load(Ordering::Relaxed), k.clone()))
            .collect();
        // The bound applies to the *ready* population: in-flight entries
        // ride on top and are never removed, so they must not count toward
        // the excess either — a burst of concurrent leaders beyond the
        // capacity would otherwise flush every just-published result.
        let excess = ready.len().saturating_sub(target);
        if excess == 0 {
            return;
        }
        ready.sort_unstable_by_key(|(stamp, _)| *stamp);
        for (_, k) in ready.into_iter().take(excess) {
            slots.remove(&k);
            evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
}

// ============================ compile cache =================================

type CacheResult = Result<Arc<dyn Mapped>, String>;
type SymbolicResult = Option<Arc<dyn SymbolicMapped>>;

/// The process-wide compiled-artifact cache: a [`FlightMap`] over
/// [`WorkloadKey`]s plus the backend registry that runs cold compiles, with
/// a second, shape-keyed [`FlightMap`] of symbolic artifacts in front of it.
/// A per-n miss probes the symbolic level first: if the backend compiled the
/// kernel's *shape* before (at any size), the artifact is instantiated in
/// closed form instead of re-running the pipeline, and the result feeds the
/// per-n LRU as usual. Backends without a symbolic path cache a `None` per
/// shape, so they pay the probe exactly once per kernel.
pub struct CompileCache {
    slots: FlightMap<WorkloadKey, CacheResult>,
    shapes: FlightMap<ShapeKey, SymbolicResult>,
    registry: BackendRegistry,
    pub stats: CacheStats,
}

/// Atomic counters exposed to metrics and the concurrency tests.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub waits: AtomicU64,
    /// Actual pipeline executions — the single-flight invariant is
    /// `compiles == misses` (each miss runs the pipeline exactly once),
    /// which eviction preserves: a re-request of an evicted key is a fresh
    /// miss *and* a fresh compile.
    pub compiles: AtomicU64,
    /// Ready entries dropped by the LRU bound.
    pub evictions: AtomicU64,
    /// Per-n misses served by instantiating an *already resident* symbolic
    /// artifact (no pipeline of any kind ran for them).
    pub symbolic_hits: AtomicU64,
    /// Symbolic (per-shape) pipeline executions that produced an artifact.
    pub symbolic_compiles: AtomicU64,
    /// Closed-form instantiations of symbolic artifacts. Together:
    /// `misses == compiles + instantiations` on the shaped path.
    pub instantiations: AtomicU64,
    /// Ready symbolic entries dropped by the shape-level LRU bound.
    pub symbolic_evictions: AtomicU64,
    /// Flights resolved poisoned-once (leader panicked or hit its
    /// deadline): the result reached its waiters but was never cached.
    pub poisoned: AtomicU64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn symbolic_hits(&self) -> u64 {
        self.symbolic_hits.load(Ordering::Relaxed)
    }

    pub fn symbolic_compiles(&self) -> u64 {
        self.symbolic_compiles.load(Ordering::Relaxed)
    }

    pub fn instantiations(&self) -> u64 {
        self.instantiations.load(Ordering::Relaxed)
    }

    pub fn symbolic_evictions(&self) -> u64 {
        self.symbolic_evictions.load(Ordering::Relaxed)
    }

    pub fn poisoned(&self) -> u64 {
        self.poisoned.load(Ordering::Relaxed)
    }
}

impl CompileCache {
    /// A cache over the default registry (paper TCPA + Morpher CGRA + the
    /// sequential reference backend) at the default capacity.
    pub fn new() -> CompileCache {
        CompileCache::with_registry(BackendRegistry::with_defaults())
    }

    /// A cache over a custom backend registry at the default capacity.
    pub fn with_registry(registry: BackendRegistry) -> CompileCache {
        CompileCache::with_capacity(registry, DEFAULT_COMPILE_CAPACITY)
    }

    /// A cache holding at most `capacity` ready artifacts (in-flight
    /// compiles ride on top of the bound and are never evicted).
    pub fn with_capacity(registry: BackendRegistry, capacity: usize) -> CompileCache {
        CompileCache::with_capacities(registry, capacity, DEFAULT_SYMBOLIC_CAPACITY)
    }

    /// A cache with both bounds explicit: at most `capacity` ready per-n
    /// artifacts and `symbolic_capacity` ready per-shape symbolic
    /// artifacts. What `CacheShards` uses to split the default budget
    /// across shards without growing the aggregate bound.
    pub fn with_capacities(
        registry: BackendRegistry,
        capacity: usize,
        symbolic_capacity: usize,
    ) -> CompileCache {
        CompileCache {
            slots: FlightMap::new(capacity),
            shapes: FlightMap::new(symbolic_capacity),
            registry,
            stats: CacheStats::default(),
        }
    }

    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// Most ready artifacts the cache will keep resident.
    pub fn capacity(&self) -> usize {
        self.slots.capacity()
    }

    /// Number of resident entries (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the compiled kernel for `spec` on `target`, compiling at most
    /// once across all threads per content address. Returns the artifact (or
    /// cached failure), how this caller observed the cache, and the key the
    /// spec resolved to.
    pub fn get_or_compile(
        &self,
        spec: &WorkloadSpec,
        target: Target,
    ) -> (CacheResult, CacheOutcome, WorkloadKey) {
        let key = WorkloadKey::of(spec, target);
        let (result, outcome) = self.get_or_compile_with_key(key, spec);
        (result, outcome, key)
    }

    /// Like [`CompileCache::get_or_compile`], but with a caller-provided
    /// key — the hot path for sessions that memoize fingerprints so cache
    /// hits skip re-rendering the spec's canonical JSON.
    pub fn get_or_compile_with_key(
        &self,
        key: WorkloadKey,
        spec: &WorkloadSpec,
    ) -> (CacheResult, CacheOutcome) {
        let target = key.target;
        let registry = &self.registry;
        let (result, outcome) = self.slots.get_or_run(
            key,
            || compile_kernel(registry, spec, target, &CancelToken::none()),
            |msg| Err(format!("{PANIC_MARKER} compile pipeline panicked: {msg}")),
            transient_result,
            &self.stats.evictions,
            &self.stats.poisoned,
        );
        match outcome {
            CacheOutcome::Hit => self.stats.hits.fetch_add(1, Ordering::Relaxed),
            CacheOutcome::Waited => self.stats.waits.fetch_add(1, Ordering::Relaxed),
            CacheOutcome::Miss => {
                self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                self.stats.misses.fetch_add(1, Ordering::Relaxed)
            }
        };
        (result, outcome)
    }

    /// Drop every *ready* artifact compiled for `target` (healthy and
    /// masked alike — a detected fault changes which artifacts are legal
    /// on that array, and the fingerprint fold means degraded keys never
    /// alias healthy ones, so dropping both is the conservative move).
    /// In-flight compiles finish and resolve; the session's retry then
    /// recompiles under the new mask. Returns the number dropped.
    pub fn invalidate_target(&self, target: Target) -> usize {
        self.slots.drop_ready(|k| k.target == target)
    }

    /// [`CompileCache::get_or_compile_shaped_cancellable`] under a fault
    /// mask. A *healthy* mask is the identity: the fold leaves the key's
    /// fingerprint unchanged and the two-level (symbolic-first) path runs
    /// as usual. A degraded mask takes the per-n path through
    /// [`crate::backend::Backend::compile_masked_cancellable`] instead —
    /// the shape level is keyed by `(shape, target)` only, so letting a
    /// masked compile feed it would alias healthy and degraded artifacts.
    /// `key` must already carry the *folded* fingerprint
    /// ([`crate::faults::FaultMask::fold_fingerprint`]), so healthy and
    /// degraded artifacts of the same kernel occupy distinct slots.
    pub fn get_or_compile_masked_cancellable(
        &self,
        key: WorkloadKey,
        shape: u64,
        spec: &WorkloadSpec,
        mask: &crate::faults::FaultMask,
        cancel: &CancelToken,
        retries: &std::cell::Cell<u64>,
    ) -> (CacheResult, CacheOutcome, SymbolicUse) {
        if mask.is_healthy() {
            return self.get_or_compile_shaped_cancellable(key, shape, spec, cancel, retries);
        }
        let target = key.target;
        let registry = &self.registry;
        let mut attempt = 0u32;
        loop {
            let (result, outcome) = self.slots.get_or_run(
                key,
                || {
                    cancel.check("compile queue")?;
                    let backend = registry.get(target).ok_or_else(|| {
                        format!("no backend registered for target `{}`", target.name())
                    })?;
                    let wl = spec.workload();
                    backend
                        .compile_masked_cancellable(&wl, mask, cancel)
                        .map(|m| Arc::from(m) as Arc<dyn Mapped>)
                        .map_err(|e| e.message)
                },
                |msg| Err(format!("{PANIC_MARKER} compile pipeline panicked: {msg}")),
                transient_result,
                &self.stats.evictions,
                &self.stats.poisoned,
            );
            match outcome {
                CacheOutcome::Hit => self.stats.hits.fetch_add(1, Ordering::Relaxed),
                CacheOutcome::Waited => self.stats.waits.fetch_add(1, Ordering::Relaxed),
                CacheOutcome::Miss => {
                    self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed)
                }
            };
            let secondhand_transient = outcome == CacheOutcome::Waited
                && result.as_ref().err().is_some_and(|e| is_transient_error(e));
            if secondhand_transient && attempt < MAX_POISON_RETRIES && !cancel.cancelled() {
                attempt += 1;
                retries.set(retries.get() + 1);
                std::thread::sleep(std::time::Duration::from_micros(50 << attempt));
                continue;
            }
            return (result, outcome, SymbolicUse::None);
        }
    }

    /// The two-level lookup: like [`CompileCache::get_or_compile_with_key`]
    /// but a per-n miss probes the symbolic (shape-keyed) level before
    /// falling back to the concrete pipeline. `shape` is the spec's
    /// [`WorkloadSpec::shape_fingerprint`] (callers memoize it alongside the
    /// concrete fingerprint). Returns additionally how the symbolic level
    /// served this request.
    pub fn get_or_compile_shaped(
        &self,
        key: WorkloadKey,
        shape: u64,
        spec: &WorkloadSpec,
    ) -> (CacheResult, CacheOutcome, SymbolicUse) {
        self.get_or_compile_shaped_cancellable(
            key,
            shape,
            spec,
            &CancelToken::none(),
            &std::cell::Cell::new(0),
        )
    }

    /// [`CompileCache::get_or_compile_shaped`] under a cooperative deadline,
    /// with bounded secondhand retry: a caller that *waited* on a flight and
    /// received a transient result (the leader panicked or hit *its*
    /// deadline — the poisoned slot is already gone) retries up to
    /// [`MAX_POISON_RETRIES`] times with a short backoff, as long as its own
    /// deadline allows. Each retry increments `retries`. Leaders never
    /// retry: their own transient result is authoritative for them.
    pub fn get_or_compile_shaped_cancellable(
        &self,
        key: WorkloadKey,
        shape: u64,
        spec: &WorkloadSpec,
        cancel: &CancelToken,
        retries: &std::cell::Cell<u64>,
    ) -> (CacheResult, CacheOutcome, SymbolicUse) {
        let mut attempt = 0u32;
        loop {
            let (result, outcome, used) = self.shaped_attempt(key, shape, spec, cancel);
            let secondhand_transient = outcome == CacheOutcome::Waited
                && result.as_ref().err().is_some_and(|e| is_transient_error(e));
            if secondhand_transient && attempt < MAX_POISON_RETRIES && !cancel.cancelled() {
                attempt += 1;
                retries.set(retries.get() + 1);
                std::thread::sleep(std::time::Duration::from_micros(50 << attempt));
                continue;
            }
            return (result, outcome, used);
        }
    }

    /// One two-level lookup attempt (the body retried by
    /// [`CompileCache::get_or_compile_shaped_cancellable`]).
    fn shaped_attempt(
        &self,
        key: WorkloadKey,
        shape: u64,
        spec: &WorkloadSpec,
        cancel: &CancelToken,
    ) -> (CacheResult, CacheOutcome, SymbolicUse) {
        let target = key.target;
        let used = std::cell::Cell::new(SymbolicUse::None);
        let (result, outcome) = self.slots.get_or_run(
            key,
            || {
                // a request that spent its whole budget queued aborts here,
                // before any pipeline runs — the poisoned-once path below
                // keeps the abort out of the cache
                cancel.check("compile queue")?;
                // leader for this (kernel, n): consult the shape level first
                let (sym, probe) = self.shapes.get_or_run(
                    ShapeKey { shape, target },
                    || self.compile_shape(spec, target),
                    // a panicking symbolic compile caches as "no symbolic
                    // path"; the concrete fallback below reproduces (and
                    // per-n-caches) whatever the pipeline does
                    |_| None,
                    |_| false,
                    &self.stats.symbolic_evictions,
                    &self.stats.poisoned,
                );
                match sym {
                    Some(artifact) => {
                        let reused = probe != CacheOutcome::Miss;
                        self.stats.instantiations.fetch_add(1, Ordering::Relaxed);
                        if reused {
                            self.stats.symbolic_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        used.set(SymbolicUse::Instantiated { reused });
                        artifact
                            .instantiate(key.n)
                            .map(Arc::from)
                            .map_err(|e| e.message)
                    }
                    None => compile_kernel(&self.registry, spec, target, cancel),
                }
            },
            |msg| Err(format!("{PANIC_MARKER} compile pipeline panicked: {msg}")),
            transient_result,
            &self.stats.evictions,
            &self.stats.poisoned,
        );
        match outcome {
            CacheOutcome::Hit => self.stats.hits.fetch_add(1, Ordering::Relaxed),
            CacheOutcome::Waited => self.stats.waits.fetch_add(1, Ordering::Relaxed),
            CacheOutcome::Miss => {
                // `compiles` keeps meaning *concrete* pipeline executions:
                // on the shaped path `misses == compiles + instantiations`
                if used.get() == SymbolicUse::None {
                    self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                }
                self.stats.misses.fetch_add(1, Ordering::Relaxed)
            }
        };
        (result, outcome, used.get())
    }

    /// Run the once-per-shape half of a backend's pipeline (`None` when the
    /// backend has no symbolic path or the spec is shape-ineligible).
    fn compile_shape(&self, spec: &WorkloadSpec, target: Target) -> SymbolicResult {
        let sym = self
            .registry
            .get(target)
            .and_then(|b| b.compile_symbolic(spec));
        match sym {
            Some(s) => {
                self.stats.symbolic_compiles.fetch_add(1, Ordering::Relaxed);
                Some(Arc::from(s))
            }
            None => None,
        }
    }
}

impl Default for CompileCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Best-effort message extraction from a caught panic payload.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}

/// Whether a cached compile result is transient (poison-once) rather than a
/// deterministic, cacheable failure.
fn transient_result(r: &CacheResult) -> bool {
    r.as_ref().err().is_some_and(|e| is_transient_error(e))
}

/// Run the expensive pipeline for one spec/target through the registry.
/// Deterministic in its inputs (the cancel token only ever converts a slow
/// compile into a transient, never-cached deadline abort), so settled
/// results — failures included — are safe to cache process-wide.
fn compile_kernel(
    registry: &BackendRegistry,
    spec: &WorkloadSpec,
    target: Target,
    cancel: &CancelToken,
) -> CacheResult {
    let backend = registry
        .get(target)
        .ok_or_else(|| format!("no backend registered for target `{}`", target.name()))?;
    let wl = spec.workload();
    backend
        .compile_cancellable(&wl, cancel)
        .map(Arc::from)
        .map_err(|e| e.message)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::spec::WorkloadCatalog;
    use std::thread;

    fn spec(name: &str, n: i64) -> WorkloadSpec {
        WorkloadCatalog::builtin().spec(name, n).expect("builtin")
    }

    #[test]
    fn hit_after_miss() {
        let cache = CompileCache::new();
        let s = spec("gemm", 8);
        let (r1, o1, k1) = cache.get_or_compile(&s, Target::Tcpa);
        assert!(r1.is_ok());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2, k2) = cache.get_or_compile(&s, Target::Tcpa);
        assert!(r2.is_ok());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(k1, k2, "same spec, same content address");
        assert_eq!(cache.stats.compiles(), 1);
        assert_eq!(cache.stats.evictions(), 0);
        assert!(Arc::ptr_eq(&r1.unwrap(), &r2.unwrap()), "shared artifact");
    }

    #[test]
    fn content_addressing_dedupes_equal_specs_from_different_sources() {
        let cache = CompileCache::new();
        let named = spec("gesummv", 8);
        // a structurally identical spec arriving "inline" over the wire
        let inline = WorkloadSpec::from_json(&named.to_json()).expect("roundtrip");
        let (_, o1, k1) = cache.get_or_compile(&named, Target::Tcpa);
        let (_, o2, k2) = cache.get_or_compile(&inline, Target::Tcpa);
        assert_eq!(o1, CacheOutcome::Miss);
        assert_eq!(o2, CacheOutcome::Hit, "inline spec must dedupe onto the builtin");
        assert_eq!(k1, k2);
        assert_eq!(cache.stats.compiles(), 1);
    }

    #[test]
    fn distinct_sizes_and_targets_get_distinct_keys() {
        let k1 = WorkloadKey::of(&spec("gemm", 8), Target::Tcpa);
        let k2 = WorkloadKey::of(&spec("gemm", 12), Target::Tcpa);
        let k3 = WorkloadKey::of(&spec("gemm", 8), Target::Cgra);
        assert_ne!(k1.fingerprint, k2.fingerprint);
        assert_ne!(k1, k3);
        assert_eq!(k1.fingerprint, k3.fingerprint, "target is outside the spec");
        assert!(k1.to_string().contains("/n8/tcpa"), "{k1}");
    }

    #[test]
    fn failures_are_cached() {
        let cache = CompileCache::new();
        // GEMM N=64 overflows the CGRA scratchpad: deterministic failure
        let s = spec("gemm", 64);
        let (r1, o1, _) = cache.get_or_compile(&s, Target::Cgra);
        assert!(r1.is_err());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2, _) = cache.get_or_compile(&s, Target::Cgra);
        assert!(r2.is_err());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(cache.stats.compiles(), 1, "error not recompiled");
    }

    #[test]
    fn concurrent_same_key_compiles_once() {
        let cache = Arc::new(CompileCache::new());
        let s = Arc::new(spec("gesummv", 8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            let s = s.clone();
            handles.push(thread::spawn(move || {
                let (r, _, _) = c.get_or_compile(&s, Target::Tcpa);
                assert!(r.is_ok());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.stats.compiles(), 1, "single-flight violated");
        assert_eq!(
            cache.stats.hits() + cache.stats.misses() + cache.stats.waits(),
            8
        );
    }

    #[test]
    fn every_registered_target_is_compilable() {
        let cache = CompileCache::new();
        let s = spec("gesummv", 8);
        for target in cache.registry().targets() {
            let (r, _, _) = cache.get_or_compile(&s, target);
            assert!(r.is_ok(), "{target:?}: {:?}", r.err());
        }
        assert_eq!(cache.stats.compiles(), Target::COUNT as u64);
    }

    #[test]
    fn unregistered_target_is_a_cached_error() {
        let cache = CompileCache::with_registry(BackendRegistry::new());
        let s = spec("gemm", 8);
        let (r, _, _) = cache.get_or_compile(&s, Target::Seq);
        assert!(r.unwrap_err().contains("no backend registered"));
        let (_, o2, _) = cache.get_or_compile(&s, Target::Seq);
        assert_eq!(o2, CacheOutcome::Hit, "lookup failures cache like compiles");
    }

    #[test]
    fn size_sweep_compiles_the_shape_once_and_instantiates_per_n() {
        let cache = CompileCache::new();
        let sizes = [4, 8, 12, 16];
        for (i, &n) in sizes.iter().enumerate() {
            let s = spec("atax", n);
            let key = WorkloadKey::of(&s, Target::Tcpa);
            let (r, o, u) = cache.get_or_compile_shaped(key, s.shape_fingerprint(), &s);
            assert!(r.is_ok(), "n={n}: {:?}", r.err());
            assert_eq!(o, CacheOutcome::Miss, "each n is a fresh per-n key");
            assert_eq!(
                u,
                SymbolicUse::Instantiated { reused: i > 0 },
                "n={n}"
            );
        }
        assert_eq!(cache.stats.symbolic_compiles(), 1, "one shape, one compile");
        assert_eq!(cache.stats.instantiations(), sizes.len() as u64);
        assert_eq!(cache.stats.symbolic_hits(), sizes.len() as u64 - 1);
        assert_eq!(cache.stats.compiles(), 0, "no concrete pipeline ran");
        assert_eq!(
            cache.stats.misses(),
            cache.stats.compiles() + cache.stats.instantiations()
        );
        // a repeat at a seen size is a plain per-n LRU hit
        let s = spec("atax", 8);
        let (_, o, u) =
            cache.get_or_compile_shaped(WorkloadKey::of(&s, Target::Tcpa), s.shape_fingerprint(), &s);
        assert_eq!(o, CacheOutcome::Hit);
        assert_eq!(u, SymbolicUse::None);
        assert_eq!(cache.stats.instantiations(), sizes.len() as u64);
    }

    #[test]
    fn backends_without_a_symbolic_path_fall_back_per_n() {
        let cache = CompileCache::new();
        for n in [4, 8] {
            let s = spec("gemm", n);
            let key = WorkloadKey::of(&s, Target::Cgra);
            let (r, o, u) = cache.get_or_compile_shaped(key, s.shape_fingerprint(), &s);
            assert!(r.is_ok());
            assert_eq!(o, CacheOutcome::Miss);
            assert_eq!(u, SymbolicUse::None, "CGRA keeps the per-n path");
        }
        assert_eq!(cache.stats.symbolic_compiles(), 0);
        assert_eq!(cache.stats.instantiations(), 0);
        assert_eq!(cache.stats.compiles(), 2);
    }

    #[test]
    fn symbolic_instantiation_failures_cache_like_concrete_failures() {
        let cache = CompileCache::new();
        // compile the shape at a feasible size first…
        let ok = spec("gemm", 8);
        let (r, _, u) =
            cache.get_or_compile_shaped(WorkloadKey::of(&ok, Target::Tcpa), ok.shape_fingerprint(), &ok);
        assert!(r.is_ok());
        assert_eq!(u, SymbolicUse::Instantiated { reused: false });
        // …then instantiate at n=32, which exceeds the FIFO budget
        let bad = spec("gemm", 32);
        let key = WorkloadKey::of(&bad, Target::Tcpa);
        let (r1, o1, u1) = cache.get_or_compile_shaped(key, bad.shape_fingerprint(), &bad);
        assert!(r1.is_err());
        assert_eq!(o1, CacheOutcome::Miss);
        assert_eq!(u1, SymbolicUse::Instantiated { reused: true });
        // the failure is resident per n like any compile failure
        let (r2, o2, u2) = cache.get_or_compile_shaped(key, bad.shape_fingerprint(), &bad);
        assert!(r2.is_err());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(u2, SymbolicUse::None);
        // and it reads identically to what the per-n pipeline reports
        let fresh = CompileCache::new();
        let (r3, _, _) = fresh.get_or_compile(&bad, Target::Tcpa);
        assert_eq!(r1.unwrap_err(), r3.unwrap_err());
    }

    #[test]
    fn lru_bound_is_enforced_and_counted() {
        // the sequential backend compiles any gemm size instantly
        let cache = CompileCache::with_capacity(BackendRegistry::with_defaults(), 3);
        for n in 4..=9 {
            let (r, o, _) = cache.get_or_compile(&spec("gemm", n), Target::Seq);
            assert!(r.is_ok());
            assert_eq!(o, CacheOutcome::Miss);
            assert!(cache.len() <= 3, "bound violated at n={n}: {}", cache.len());
        }
        assert_eq!(cache.stats.evictions(), 3);
        // the oldest key was evicted: re-requesting it is a miss again —
        // and the compiles == misses identity survives the round trip
        let (_, o, _) = cache.get_or_compile(&spec("gemm", 4), Target::Seq);
        assert_eq!(o, CacheOutcome::Miss, "evicted entries recompile");
        assert_eq!(cache.stats.compiles(), cache.stats.misses());
        // the freshest key is still resident
        let (_, o, _) = cache.get_or_compile(&spec("gemm", 9), Target::Seq);
        assert_eq!(o, CacheOutcome::Hit);
    }

    /// Test backend that panics on its first compile and then behaves like
    /// the sequential reference — the minimal "crashed leader, healthy
    /// retry" backend the poison-once path exists for.
    struct FlakyBackend {
        inner: crate::backend::SeqBackend,
        armed: std::sync::atomic::AtomicBool,
    }

    impl FlakyBackend {
        fn new() -> FlakyBackend {
            FlakyBackend {
                inner: crate::backend::SeqBackend::new(),
                armed: std::sync::atomic::AtomicBool::new(true),
            }
        }
    }

    impl crate::backend::Backend for FlakyBackend {
        fn target(&self) -> Target {
            Target::Seq
        }

        fn name(&self) -> &'static str {
            "flaky-seq"
        }

        fn compile(
            &self,
            wl: &crate::bench::workloads::Workload,
        ) -> Result<Box<dyn Mapped>, crate::backend::CompileError> {
            if self.armed.swap(false, Ordering::SeqCst) {
                panic!("injected compile panic");
            }
            crate::backend::Backend::compile(&self.inner, wl)
        }
    }

    #[test]
    fn panicked_leader_poisons_once_and_the_next_request_retries_fresh() {
        let mut registry = BackendRegistry::new();
        registry.register(Arc::new(FlakyBackend::new()));
        let cache = CompileCache::with_registry(registry);
        let s = spec("gemm", 8);
        let (r1, o1, _) = cache.get_or_compile(&s, Target::Seq);
        let e1 = r1.expect_err("first compile panics");
        assert!(e1.contains(PANIC_MARKER), "{e1}");
        assert!(is_transient_error(&e1));
        assert_eq!(o1, CacheOutcome::Miss);
        assert_eq!(cache.stats.poisoned(), 1, "flight resolved poisoned-once");
        assert_eq!(cache.len(), 0, "the poisoned slot is not resident");
        // poison never sticks: the same key retries fresh and succeeds
        let (r2, o2, _) = cache.get_or_compile(&s, Target::Seq);
        assert!(r2.is_ok(), "{:?}", r2.err());
        assert_eq!(o2, CacheOutcome::Miss, "fresh flight, not a cached panic");
        assert_eq!(cache.stats.compiles(), cache.stats.misses());
        // …and from here on it is an ordinary resident artifact
        let (_, o3, _) = cache.get_or_compile(&s, Target::Seq);
        assert_eq!(o3, CacheOutcome::Hit);
    }

    #[test]
    fn deadline_aborts_are_transient_and_never_cached() {
        let cache = CompileCache::new();
        let s = spec("gemm", 8);
        let key = WorkloadKey::of(&s, Target::Tcpa);
        let expired = CancelToken::deadline_in(std::time::Duration::ZERO);
        let retries = std::cell::Cell::new(0u64);
        let (r1, o1, _) = cache.get_or_compile_shaped_cancellable(
            key,
            s.shape_fingerprint(),
            &s,
            &expired,
            &retries,
        );
        let e1 = r1.expect_err("expired deadline aborts the compile");
        assert!(crate::backend::is_deadline_error(&e1), "{e1}");
        assert_eq!(o1, CacheOutcome::Miss);
        assert_eq!(retries.get(), 0, "leaders never retry their own abort");
        assert_eq!(cache.stats.poisoned(), 1);
        // the abort did not alias the key: an undeadlined request compiles
        let (r2, o2, _) = cache.get_or_compile_shaped(key, s.shape_fingerprint(), &s);
        assert!(r2.is_ok(), "{:?}", r2.err());
        assert_eq!(o2, CacheOutcome::Miss);
    }

    #[test]
    fn waiters_on_a_poisoned_flight_retry_and_recover() {
        use std::sync::atomic::AtomicBool;

        /// Like [`FlakyBackend`], but the first (panicking) compile parks on
        /// a gate so the test can guarantee a waiter joined the flight.
        struct GatedFlaky {
            inner: crate::backend::SeqBackend,
            armed: AtomicBool,
            gate: Arc<(Mutex<bool>, Condvar)>,
        }

        impl crate::backend::Backend for GatedFlaky {
            fn target(&self) -> Target {
                Target::Seq
            }

            fn name(&self) -> &'static str {
                "gated-flaky-seq"
            }

            fn compile(
                &self,
                wl: &crate::bench::workloads::Workload,
            ) -> Result<Box<dyn Mapped>, crate::backend::CompileError> {
                if self.armed.swap(false, Ordering::SeqCst) {
                    let (lock, cv) = &*self.gate;
                    let mut open = lock.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                    panic!("injected leader panic");
                }
                crate::backend::Backend::compile(&self.inner, wl)
            }
        }

        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let mut registry = BackendRegistry::new();
        registry.register(Arc::new(GatedFlaky {
            inner: crate::backend::SeqBackend::new(),
            armed: AtomicBool::new(true),
            gate: gate.clone(),
        }));
        let cache = Arc::new(CompileCache::with_registry(registry));
        let s = Arc::new(spec("gemm", 8));
        let key = WorkloadKey::of(&s, Target::Seq);
        let shape = s.shape_fingerprint();

        let spawn_probe = |c: Arc<CompileCache>, s: Arc<WorkloadSpec>| {
            thread::spawn(move || {
                let retries = std::cell::Cell::new(0u64);
                let (r, o, _) = c.get_or_compile_shaped_cancellable(
                    key,
                    shape,
                    &s,
                    &CancelToken::none(),
                    &retries,
                );
                (r, o, retries.get())
            })
        };
        let leader = spawn_probe(cache.clone(), s.clone());
        let waiter = spawn_probe(cache.clone(), s.clone());
        // both probes are in the map (one leading, one joined or about to
        // lead the retry) before the gate opens and the leader panics
        std::thread::sleep(std::time::Duration::from_millis(20));
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        let (lr, _, l_retries) = leader.join().unwrap();
        let (wr, _, w_retries) = waiter.join().unwrap();
        // exactly one probe ate the injected panic; the other — whether it
        // waited (and retried the poisoned flight) or led fresh — recovered
        let (failed, recovered) = if lr.is_err() { (lr, wr) } else { (wr, lr) };
        let msg = failed.expect_err("one probe observes the panic");
        assert!(msg.contains(PANIC_MARKER), "{msg}");
        assert!(recovered.is_ok(), "waiters never strand on a poisoned flight");
        assert_eq!(cache.stats.poisoned(), 1);
        assert!(l_retries + w_retries <= MAX_POISON_RETRIES as u64);
    }

    #[test]
    fn invalidate_target_drops_ready_entries_for_that_target_only() {
        let cache = CompileCache::new();
        cache.get_or_compile(&spec("gemm", 8), Target::Seq);
        cache.get_or_compile(&spec("gemm", 8), Target::Tcpa);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.invalidate_target(Target::Tcpa), 1);
        let (_, o, _) = cache.get_or_compile(&spec("gemm", 8), Target::Seq);
        assert_eq!(o, CacheOutcome::Hit, "other targets keep their artifacts");
        let (_, o, _) = cache.get_or_compile(&spec("gemm", 8), Target::Tcpa);
        assert_eq!(o, CacheOutcome::Miss, "invalidated entries recompile");
        assert_eq!(cache.invalidate_target(Target::Cgra), 0, "nothing resident");
    }

    #[test]
    fn masked_compiles_key_apart_from_healthy_ones() {
        use crate::faults::FaultMask;
        let cache = CompileCache::new();
        let s = spec("gemm", 4);
        let retries = std::cell::Cell::new(0u64);
        let healthy_key = WorkloadKey::of(&s, Target::Tcpa);
        let mask = FaultMask::healthy().with_failed_pe(5);
        let masked_key = WorkloadKey {
            fingerprint: mask.fold_fingerprint(s.fingerprint()),
            ..healthy_key
        };
        assert_ne!(healthy_key.fingerprint, masked_key.fingerprint);
        let (h, _, _) = cache.get_or_compile_masked_cancellable(
            healthy_key,
            s.shape_fingerprint(),
            &s,
            &FaultMask::healthy(),
            &CancelToken::none(),
            &retries,
        );
        let (m, o, u) = cache.get_or_compile_masked_cancellable(
            masked_key,
            s.shape_fingerprint(),
            &s,
            &mask,
            &CancelToken::none(),
            &retries,
        );
        let (h, m) = (h.expect("healthy compiles"), m.expect("masked compiles"));
        assert_eq!(o, CacheOutcome::Miss);
        assert_eq!(u, SymbolicUse::None, "masked path skips the shape level");
        assert_ne!(h.stats().arch, m.stats().arch, "degraded arch is distinct");
        // a repeat masked request hits its own slot — no aliasing either way
        let (_, o2, _) = cache.get_or_compile_masked_cancellable(
            masked_key,
            s.shape_fingerprint(),
            &s,
            &mask,
            &CancelToken::none(),
            &retries,
        );
        assert_eq!(o2, CacheOutcome::Hit);
    }

    #[test]
    fn lru_recency_is_refreshed_by_hits() {
        let cache = CompileCache::with_capacity(BackendRegistry::with_defaults(), 2);
        let (a, b, c) = (spec("gemm", 4), spec("gemm", 5), spec("gemm", 6));
        cache.get_or_compile(&a, Target::Seq);
        cache.get_or_compile(&b, Target::Seq);
        // touch `a` so `b` becomes the LRU victim
        let (_, o, _) = cache.get_or_compile(&a, Target::Seq);
        assert_eq!(o, CacheOutcome::Hit);
        cache.get_or_compile(&c, Target::Seq);
        let (_, oa, _) = cache.get_or_compile(&a, Target::Seq);
        assert_eq!(oa, CacheOutcome::Hit, "recently-used entry survived");
        let (_, ob, _) = cache.get_or_compile(&b, Target::Seq);
        assert_eq!(ob, CacheOutcome::Miss, "stale entry was the victim");
    }
}
