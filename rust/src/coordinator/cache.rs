//! Shared, thread-safe compile cache with single-flight semantics, keyed by
//! *content address*.
//!
//! The map/schedule pipeline ([`crate::backend::Backend::compile`] over the
//! registered backends) dominates request latency, so its results are cached
//! behind an `Arc<RwLock<HashMap>>` keyed by [`WorkloadKey`] — a stable
//! FNV-1a fingerprint of the [`WorkloadSpec`] plus problem size and target —
//! and shared by every worker of a [`super::pool`]. Content addressing means
//! an *inline* user-submitted spec that is structurally identical to a
//! catalog entry (or to another client's submission) dedupes onto the same
//! artifact: the cache never needs to know where a spec came from.
//!
//! When N workers race on the same cold key, exactly one runs the pipeline
//! (the *leader*); the rest park on a condvar and receive the leader's
//! result — each distinct kernel is compiled once per process, which is what
//! amortizes compile time across invocations (the §V-A batching argument at
//! service scale).
//!
//! The cache is target-agnostic: it stores `Arc<dyn Mapped>` and resolves
//! the pipeline through its [`BackendRegistry`], so a new backend plugs in
//! by registration alone — no cache change, no new enum variant.
//!
//! Compile failures are cached too: the pipeline is deterministic, so a
//! failing (spec, target) would fail identically on every retry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::backend::{BackendRegistry, Mapped, Target};
use crate::bench::spec::WorkloadSpec;

/// Content-addressed cache key: one compiled artifact per (spec fingerprint,
/// size, target). The size rides along for observability — it is already
/// folded into the fingerprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadKey {
    /// [`WorkloadSpec::fingerprint`] — FNV-1a over the spec's canonical JSON.
    pub fingerprint: u64,
    /// Problem size the spec was built at.
    pub n: i64,
    pub target: Target,
}

impl WorkloadKey {
    /// The key a spec compiles under for a target.
    pub fn of(spec: &WorkloadSpec, target: Target) -> WorkloadKey {
        WorkloadKey {
            fingerprint: spec.fingerprint(),
            n: spec.n,
            target,
        }
    }
}

impl std::fmt::Display for WorkloadKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:016x}/n{}/{}",
            self.fingerprint,
            self.n,
            self.target.name()
        )
    }
}

/// What `get_or_compile` observed for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Result was already cached.
    Hit,
    /// This caller ran the compile pipeline.
    Miss,
    /// Another caller was compiling; this one waited for its result.
    Waited,
}

type CacheResult = Result<Arc<dyn Mapped>, String>;

/// Rendezvous for callers that arrive while the leader is compiling.
struct Flight {
    done: Mutex<Option<CacheResult>>,
    cv: Condvar,
}

enum Slot {
    InFlight(Arc<Flight>),
    Ready(CacheResult),
}

/// What a caller holds after consulting the slot map.
enum Claim {
    Ready(CacheResult),
    Join(Arc<Flight>),
    Lead(Arc<Flight>),
}

/// Lock-striped-enough for this workload: reads (the steady state) take the
/// RwLock in shared mode; the write lock is held only to flip slot states,
/// never across a compile.
pub struct CompileCache {
    slots: RwLock<HashMap<WorkloadKey, Slot>>,
    registry: BackendRegistry,
    pub stats: CacheStats,
}

/// Atomic counters exposed to metrics and the concurrency tests.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub waits: AtomicU64,
    /// Actual pipeline executions — the single-flight invariant is
    /// `compiles == distinct keys requested`.
    pub compiles: AtomicU64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }
}

impl CompileCache {
    /// A cache over the default registry (paper TCPA + Morpher CGRA + the
    /// sequential reference backend).
    pub fn new() -> CompileCache {
        CompileCache::with_registry(BackendRegistry::with_defaults())
    }

    /// A cache over a custom backend registry.
    pub fn with_registry(registry: BackendRegistry) -> CompileCache {
        CompileCache {
            slots: RwLock::new(HashMap::new()),
            registry,
            stats: CacheStats::default(),
        }
    }

    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// Number of resident entries (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the compiled kernel for `spec` on `target`, compiling at most
    /// once across all threads per content address. Returns the artifact (or
    /// cached failure), how this caller observed the cache, and the key the
    /// spec resolved to.
    pub fn get_or_compile(
        &self,
        spec: &WorkloadSpec,
        target: Target,
    ) -> (CacheResult, CacheOutcome, WorkloadKey) {
        let key = WorkloadKey::of(spec, target);
        let (result, outcome) = self.get_or_compile_with_key(key, spec);
        (result, outcome, key)
    }

    /// Like [`CompileCache::get_or_compile`], but with a caller-provided
    /// key — the hot path for sessions that memoize fingerprints so cache
    /// hits skip re-rendering the spec's canonical JSON.
    pub fn get_or_compile_with_key(
        &self,
        key: WorkloadKey,
        spec: &WorkloadSpec,
    ) -> (CacheResult, CacheOutcome) {
        let target = key.target;
        // fast path: shared read lock
        let seen = {
            let slots = self.slots.read().unwrap();
            match slots.get(&key) {
                Some(Slot::Ready(r)) => Some(Claim::Ready(r.clone())),
                Some(Slot::InFlight(f)) => Some(Claim::Join(f.clone())),
                None => None,
            }
        };
        let claim = match seen {
            Some(c) => c,
            None => {
                // slow path: claim or join the flight under the write lock
                let mut slots = self.slots.write().unwrap();
                let existing = match slots.get(&key) {
                    Some(Slot::Ready(r)) => Some(Claim::Ready(r.clone())),
                    Some(Slot::InFlight(f)) => Some(Claim::Join(f.clone())),
                    None => None,
                };
                match existing {
                    Some(c) => c,
                    None => {
                        let flight = Arc::new(Flight {
                            done: Mutex::new(None),
                            cv: Condvar::new(),
                        });
                        slots.insert(key, Slot::InFlight(flight.clone()));
                        Claim::Lead(flight)
                    }
                }
            }
        };

        match claim {
            Claim::Ready(r) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                (r, CacheOutcome::Hit)
            }
            Claim::Join(flight) => (self.wait(&flight), CacheOutcome::Waited),
            Claim::Lead(flight) => {
                // leader: compile with no lock held; a panic inside the
                // pipeline must still resolve the flight, or every waiter
                // (and all future requests for this key) would hang forever
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                let registry = &self.registry;
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || compile_kernel(registry, spec, target),
                ))
                .unwrap_or_else(|p| {
                    Err(format!("compile pipeline panicked: {}", panic_message(&p)))
                });

                {
                    let mut slots = self.slots.write().unwrap();
                    slots.insert(key, Slot::Ready(result.clone()));
                }
                {
                    let mut done = flight.done.lock().unwrap();
                    *done = Some(result.clone());
                }
                flight.cv.notify_all();
                (result, CacheOutcome::Miss)
            }
        }
    }

    fn wait(&self, flight: &Flight) -> CacheResult {
        self.stats.waits.fetch_add(1, Ordering::Relaxed);
        let mut done = flight.done.lock().unwrap();
        while done.is_none() {
            done = flight.cv.wait(done).unwrap();
        }
        done.as_ref().unwrap().clone()
    }
}

impl Default for CompileCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Best-effort message extraction from a caught panic payload.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}

/// Run the expensive pipeline for one spec/target through the registry.
/// Deterministic in its inputs, so results (including failures) are safe to
/// cache process-wide.
fn compile_kernel(
    registry: &BackendRegistry,
    spec: &WorkloadSpec,
    target: Target,
) -> CacheResult {
    let backend = registry
        .get(target)
        .ok_or_else(|| format!("no backend registered for target `{}`", target.name()))?;
    let wl = spec.workload();
    backend
        .compile(&wl)
        .map(Arc::from)
        .map_err(|e| e.message)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::spec::WorkloadCatalog;
    use std::thread;

    fn spec(name: &str, n: i64) -> WorkloadSpec {
        WorkloadCatalog::builtin().spec(name, n).expect("builtin")
    }

    #[test]
    fn hit_after_miss() {
        let cache = CompileCache::new();
        let s = spec("gemm", 8);
        let (r1, o1, k1) = cache.get_or_compile(&s, Target::Tcpa);
        assert!(r1.is_ok());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2, k2) = cache.get_or_compile(&s, Target::Tcpa);
        assert!(r2.is_ok());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(k1, k2, "same spec, same content address");
        assert_eq!(cache.stats.compiles(), 1);
        assert!(Arc::ptr_eq(&r1.unwrap(), &r2.unwrap()), "shared artifact");
    }

    #[test]
    fn content_addressing_dedupes_equal_specs_from_different_sources() {
        let cache = CompileCache::new();
        let named = spec("gesummv", 8);
        // a structurally identical spec arriving "inline" over the wire
        let inline = WorkloadSpec::from_json(&named.to_json()).expect("roundtrip");
        let (_, o1, k1) = cache.get_or_compile(&named, Target::Tcpa);
        let (_, o2, k2) = cache.get_or_compile(&inline, Target::Tcpa);
        assert_eq!(o1, CacheOutcome::Miss);
        assert_eq!(o2, CacheOutcome::Hit, "inline spec must dedupe onto the builtin");
        assert_eq!(k1, k2);
        assert_eq!(cache.stats.compiles(), 1);
    }

    #[test]
    fn distinct_sizes_and_targets_get_distinct_keys() {
        let k1 = WorkloadKey::of(&spec("gemm", 8), Target::Tcpa);
        let k2 = WorkloadKey::of(&spec("gemm", 12), Target::Tcpa);
        let k3 = WorkloadKey::of(&spec("gemm", 8), Target::Cgra);
        assert_ne!(k1.fingerprint, k2.fingerprint);
        assert_ne!(k1, k3);
        assert_eq!(k1.fingerprint, k3.fingerprint, "target is outside the spec");
        assert!(k1.to_string().contains("/n8/tcpa"), "{k1}");
    }

    #[test]
    fn failures_are_cached() {
        let cache = CompileCache::new();
        // GEMM N=64 overflows the CGRA scratchpad: deterministic failure
        let s = spec("gemm", 64);
        let (r1, o1, _) = cache.get_or_compile(&s, Target::Cgra);
        assert!(r1.is_err());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2, _) = cache.get_or_compile(&s, Target::Cgra);
        assert!(r2.is_err());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(cache.stats.compiles(), 1, "error not recompiled");
    }

    #[test]
    fn concurrent_same_key_compiles_once() {
        let cache = Arc::new(CompileCache::new());
        let s = Arc::new(spec("gesummv", 8));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            let s = s.clone();
            handles.push(thread::spawn(move || {
                let (r, _, _) = c.get_or_compile(&s, Target::Tcpa);
                assert!(r.is_ok());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.stats.compiles(), 1, "single-flight violated");
        assert_eq!(
            cache.stats.hits() + cache.stats.misses() + cache.stats.waits(),
            8
        );
    }

    #[test]
    fn every_registered_target_is_compilable() {
        let cache = CompileCache::new();
        let s = spec("gesummv", 8);
        for target in cache.registry().targets() {
            let (r, _, _) = cache.get_or_compile(&s, target);
            assert!(r.is_ok(), "{target:?}: {:?}", r.err());
        }
        assert_eq!(cache.stats.compiles(), Target::COUNT as u64);
    }

    #[test]
    fn unregistered_target_is_a_cached_error() {
        let cache = CompileCache::with_registry(BackendRegistry::new());
        let s = spec("gemm", 8);
        let (r, _, _) = cache.get_or_compile(&s, Target::Seq);
        assert!(r.unwrap_err().contains("no backend registered"));
        let (_, o2, _) = cache.get_or_compile(&s, Target::Seq);
        assert_eq!(o2, CacheOutcome::Hit, "lookup failures cache like compiles");
    }
}
