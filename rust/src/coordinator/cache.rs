//! Shared, thread-safe compile cache with single-flight semantics.
//!
//! The map/schedule pipeline ([`crate::backend::Backend::compile`] over the
//! registered backends) dominates request latency, so its results are cached behind an
//! `Arc<RwLock<HashMap>>` keyed by `(BenchId, n, Target)` and shared by
//! every worker of a [`super::pool`]. When N workers race on the same cold
//! key, exactly one runs the pipeline (the *leader*); the rest park on a
//! condvar and receive the leader's result — each distinct kernel is
//! compiled once per process, which is what amortizes compile time across
//! invocations (the §V-A batching argument at service scale).
//!
//! The cache is target-agnostic: it stores `Arc<dyn Mapped>` and resolves
//! the pipeline through its [`BackendRegistry`], so a new backend plugs in
//! by registration alone — no cache change, no new enum variant.
//!
//! Compile failures are cached too: the pipeline is deterministic, so a
//! failing `(bench, n, target)` would fail identically on every retry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::backend::{BackendRegistry, Mapped, Target};
use crate::bench::workloads::{build, BenchId};

/// Cache key: one compiled artifact per benchmark instance per target.
pub type CacheKey = (BenchId, i64, Target);

/// What `get_or_compile` observed for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Result was already cached.
    Hit,
    /// This caller ran the compile pipeline.
    Miss,
    /// Another caller was compiling; this one waited for its result.
    Waited,
}

type CacheResult = Result<Arc<dyn Mapped>, String>;

/// Rendezvous for callers that arrive while the leader is compiling.
struct Flight {
    done: Mutex<Option<CacheResult>>,
    cv: Condvar,
}

enum Slot {
    InFlight(Arc<Flight>),
    Ready(CacheResult),
}

/// What a caller holds after consulting the slot map.
enum Claim {
    Ready(CacheResult),
    Join(Arc<Flight>),
    Lead(Arc<Flight>),
}

/// Lock-striped-enough for this workload: reads (the steady state) take the
/// RwLock in shared mode; the write lock is held only to flip slot states,
/// never across a compile.
pub struct CompileCache {
    slots: RwLock<HashMap<CacheKey, Slot>>,
    registry: BackendRegistry,
    pub stats: CacheStats,
}

/// Atomic counters exposed to metrics and the concurrency tests.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub waits: AtomicU64,
    /// Actual pipeline executions — the single-flight invariant is
    /// `compiles == distinct keys requested`.
    pub compiles: AtomicU64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }
}

impl CompileCache {
    /// A cache over the default registry (paper TCPA + Morpher CGRA + the
    /// sequential reference backend).
    pub fn new() -> CompileCache {
        CompileCache::with_registry(BackendRegistry::with_defaults())
    }

    /// A cache over a custom backend registry.
    pub fn with_registry(registry: BackendRegistry) -> CompileCache {
        CompileCache {
            slots: RwLock::new(HashMap::new()),
            registry,
            stats: CacheStats::default(),
        }
    }

    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// Number of resident entries (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the compiled kernel for `key`, compiling at most once across
    /// all threads.
    pub fn get_or_compile(&self, key: CacheKey) -> (CacheResult, CacheOutcome) {
        // fast path: shared read lock
        let seen = {
            let slots = self.slots.read().unwrap();
            match slots.get(&key) {
                Some(Slot::Ready(r)) => Some(Claim::Ready(r.clone())),
                Some(Slot::InFlight(f)) => Some(Claim::Join(f.clone())),
                None => None,
            }
        };
        let claim = match seen {
            Some(c) => c,
            None => {
                // slow path: claim or join the flight under the write lock
                let mut slots = self.slots.write().unwrap();
                let existing = match slots.get(&key) {
                    Some(Slot::Ready(r)) => Some(Claim::Ready(r.clone())),
                    Some(Slot::InFlight(f)) => Some(Claim::Join(f.clone())),
                    None => None,
                };
                match existing {
                    Some(c) => c,
                    None => {
                        let flight = Arc::new(Flight {
                            done: Mutex::new(None),
                            cv: Condvar::new(),
                        });
                        slots.insert(key, Slot::InFlight(flight.clone()));
                        Claim::Lead(flight)
                    }
                }
            }
        };

        match claim {
            Claim::Ready(r) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                (r, CacheOutcome::Hit)
            }
            Claim::Join(flight) => (self.wait(&flight), CacheOutcome::Waited),
            Claim::Lead(flight) => {
                // leader: compile with no lock held; a panic inside the
                // pipeline must still resolve the flight, or every waiter
                // (and all future requests for this key) would hang forever
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                let registry = &self.registry;
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || compile_kernel(registry, key),
                ))
                .unwrap_or_else(|p| {
                    Err(format!("compile pipeline panicked: {}", panic_message(&p)))
                });

                {
                    let mut slots = self.slots.write().unwrap();
                    slots.insert(key, Slot::Ready(result.clone()));
                }
                {
                    let mut done = flight.done.lock().unwrap();
                    *done = Some(result.clone());
                }
                flight.cv.notify_all();
                (result, CacheOutcome::Miss)
            }
        }
    }

    fn wait(&self, flight: &Flight) -> CacheResult {
        self.stats.waits.fetch_add(1, Ordering::Relaxed);
        let mut done = flight.done.lock().unwrap();
        while done.is_none() {
            done = flight.cv.wait(done).unwrap();
        }
        done.as_ref().unwrap().clone()
    }
}

impl Default for CompileCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Best-effort message extraction from a caught panic payload.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}

/// Run the expensive pipeline for one key through the registry.
/// Deterministic in its inputs, so results (including failures) are safe to
/// cache process-wide.
fn compile_kernel(registry: &BackendRegistry, key: CacheKey) -> CacheResult {
    let (bench, n, target) = key;
    let backend = registry
        .get(target)
        .ok_or_else(|| format!("no backend registered for target `{}`", target.name()))?;
    let wl = build(bench, n);
    backend
        .compile(&wl)
        .map(Arc::from)
        .map_err(|e| e.message)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn hit_after_miss() {
        let cache = CompileCache::new();
        let key = (BenchId::Gemm, 8, Target::Tcpa);
        let (r1, o1) = cache.get_or_compile(key);
        assert!(r1.is_ok());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2) = cache.get_or_compile(key);
        assert!(r2.is_ok());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(cache.stats.compiles(), 1);
        assert!(Arc::ptr_eq(&r1.unwrap(), &r2.unwrap()), "shared artifact");
    }

    #[test]
    fn failures_are_cached() {
        let cache = CompileCache::new();
        // GEMM N=64 overflows the CGRA scratchpad: deterministic failure
        let key = (BenchId::Gemm, 64, Target::Cgra);
        let (r1, o1) = cache.get_or_compile(key);
        assert!(r1.is_err());
        assert_eq!(o1, CacheOutcome::Miss);
        let (r2, o2) = cache.get_or_compile(key);
        assert!(r2.is_err());
        assert_eq!(o2, CacheOutcome::Hit);
        assert_eq!(cache.stats.compiles(), 1, "error not recompiled");
    }

    #[test]
    fn concurrent_same_key_compiles_once() {
        let cache = Arc::new(CompileCache::new());
        let key = (BenchId::Gesummv, 8, Target::Tcpa);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            handles.push(thread::spawn(move || {
                let (r, _) = c.get_or_compile(key);
                assert!(r.is_ok());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.stats.compiles(), 1, "single-flight violated");
        assert_eq!(
            cache.stats.hits() + cache.stats.misses() + cache.stats.waits(),
            8
        );
    }

    #[test]
    fn every_registered_target_is_compilable() {
        let cache = CompileCache::new();
        for target in cache.registry().targets() {
            let (r, _) = cache.get_or_compile((BenchId::Gesummv, 8, target));
            assert!(r.is_ok(), "{target:?}: {:?}", r.err());
        }
        assert_eq!(cache.stats.compiles(), Target::COUNT as u64);
    }

    #[test]
    fn unregistered_target_is_a_cached_error() {
        let cache = CompileCache::with_registry(BackendRegistry::new());
        let key = (BenchId::Gemm, 8, Target::Seq);
        let (r, _) = cache.get_or_compile(key);
        assert!(r.unwrap_err().contains("no backend registered"));
        let (_, o2) = cache.get_or_compile(key);
        assert_eq!(o2, CacheOutcome::Hit, "lookup failures cache like compiles");
    }
}
