//! `repro` — the leader entrypoint: maps/simulates benchmarks and regenerates
//! every table and figure of the paper's evaluation.
//!
//! ```text
//! repro table1                 # qualitative toolchain features (Table I)
//! repro table2 [--quick]      # mapping results (Table II)
//! repro table3                 # FPGA resources + power (Table III)
//! repro fig6 [--bench gemm] [--sizes 8,12,16,20]
//! repro fig7 [--quick]        # speedups at the paper's sizes
//! repro fig8 [--quick]        # PE-count / unroll scaling incl. bounds
//! repro asic                   # §V-B2/§V-C2 published-chip comparison
//! repro validate [--bench gemm] [--n 8]   # end-to-end numeric validation
//! repro serve [--workers 4] [--requests 24] [--trace mixed|gemm]
//!             [--target tcpa|cgra|seq] [--compare]
//!                              # synthetic trace through the worker pool +
//!                              # shared content-addressed compile cache
//! repro serve --requests <file.jsonl|->  [--workers 4] [--shards S]
//!                              # JSON wire protocol: newline-delimited
//!                              # requests (catalog name or inline workload
//!                              # spec) in, completion-order JSON responses
//!                              # out, correlated by the echoed client id
//! repro serve --listen <addr|path> [--workers 4] [--shards S]
//!                              # socket front-end: TCP (host:port) or
//!                              # Unix-domain (path or unix:path) listener
//!                              # speaking the same JSONL wire protocol to
//!                              # many concurrent connections, over S
//!                              # fingerprint-sharded cache pairs
//! repro serve --fault-seed S --fault-rate R
//!                              # chaos builds only (--features
//!                              # fault-injection): arm deterministic PE
//!                              # fail-stop injection in every worker —
//!                              # detections quarantine the PE, invalidate
//!                              # the target's cached artifacts and remap
//! repro analyze --all          # static legality proof for every builtin
//! repro analyze <name> <n>     # … for one workload at one size, plus the
//!                              # n-independent symbolic TCPA proof
//! repro faults <name> <n> [--pe P] [--seed S]
//!                              # fault-plane drill: serve healthy, then
//!                              # under a fail-stop mask (spare-aware
//!                              # remap), then redundantly (DMR/TMR voting
//!                              # under an armed SEU mask), with the fault
//!                              # counters reconciled at the end
//! repro lint [<root>]          # source invariants (match-arm, hot-path
//!                              # unwrap, sim hot-loop allocation rules)
//! repro paula <file.paula>    # compile a PAULA program onto the TCPA
//! repro all [--quick]         # everything above, in order
//! ```

use std::time::Duration;

use repro::backend::Target;
use repro::bench::harness;
use repro::bench::spec::WorkloadCatalog;
use repro::bench::workloads::BenchId;
use repro::coordinator::{pool, wire, Metrics, Request, Response};
use repro::ir::paula;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::compile;
use repro::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let quick = args.flag("quick");
    match cmd {
        "table1" => println!("{}", harness::table1().render()),
        "table2" => {
            println!("{}", harness::table2(&BenchId::PAPER5, 4, 4, quick).render());
        }
        "table3" => println!("{}", harness::table3().render()),
        "fig6" => {
            let benches: Vec<BenchId> = match args.opt("bench") {
                Some(b) => vec![BenchId::parse(b).expect("unknown benchmark")],
                None => BenchId::ALL.to_vec(),
            };
            for id in benches {
                let sizes: Vec<i64> = match args.opt("sizes") {
                    Some(_) => args
                        .opt_usize_list("sizes", &[])
                        .into_iter()
                        .map(|x| x as i64)
                        .collect(),
                    None => harness::fig6_sizes(id),
                };
                println!("== Fig. 6: {} ==", id.name());
                println!("{}", harness::fig6(id, &sizes, quick).render());
            }
        }
        "fig7" => println!("{}", harness::fig7(quick).render()),
        "fig8" => println!("{}", harness::fig8(quick).render()),
        "asic" => println!("{}", harness::asic_table().render()),
        "validate" => {
            let benches: Vec<BenchId> = match args.opt("bench") {
                Some(b) => vec![BenchId::parse(b).expect("unknown benchmark")],
                None => BenchId::ALL.to_vec(),
            };
            let n = args.opt_usize("n", 8) as i64;
            for id in benches {
                match harness::validate(id, n, 42) {
                    Ok(lines) => {
                        println!("[ok] {} (N={n})", id.name());
                        for l in lines {
                            println!("     {l}");
                        }
                    }
                    Err(e) => {
                        eprintln!("[FAIL] {} (N={n}): {e}", id.name());
                        std::process::exit(1);
                    }
                }
            }
        }
        "serve" => {
            let workers = args.opt_usize("workers", 4);
            // resilience knobs: a bounded admission queue (overflow is shed
            // with a typed error response) and a deadline applied to
            // requests that do not carry their own
            let pool_config = pool::PoolConfig {
                queue_cap: args.opt("queue-cap").map(|v| {
                    v.parse::<usize>().unwrap_or_else(|_| {
                        eprintln!("--queue-cap wants a non-negative integer, got `{v}`");
                        std::process::exit(2);
                    })
                }),
                default_deadline_ms: args.opt("default-deadline-ms").map(|v| {
                    v.parse::<u64>().unwrap_or_else(|_| {
                        eprintln!("--default-deadline-ms wants a non-negative integer, got `{v}`");
                        std::process::exit(2);
                    })
                }),
                ..pool::PoolConfig::default()
            };
            // `--fault-seed`/`--fault-rate` arm deterministic PE fail-stop
            // injection in every worker (chaos builds only; the plain build
            // rejects the flags rather than silently serving healthy)
            #[cfg(feature = "fault-injection")]
            let pool_config = {
                let mut config = pool_config;
                if args.opt("fault-seed").is_some() || args.opt("fault-rate").is_some() {
                    let seed = args.opt_u64("fault-seed", 42);
                    let rate = args.opt_usize("fault-rate", 1000).min(1000) as u16;
                    config.faults = Some(std::sync::Arc::new(
                        repro::coordinator::FaultPlan::new(seed)
                            .with_rate(repro::coordinator::FaultSite::PeFailStop, rate),
                    ));
                }
                config
            };
            #[cfg(not(feature = "fault-injection"))]
            if args.opt("fault-seed").is_some() || args.opt("fault-rate").is_some() {
                eprintln!(
                    "--fault-seed/--fault-rate need a chaos build: \
                     cargo run --features fault-injection -- serve ..."
                );
                std::process::exit(2);
            }
            // keep a handle on the armed plan so the final report can show
            // the per-site injected counters next to the fault counters
            #[cfg(feature = "fault-injection")]
            let fault_plan = pool_config.faults.clone();
            // shard count for both cache levels (fingerprint % S routing);
            // 1 keeps the classic single-cache plane
            let shards = args.opt_usize("shards", 1);
            // `--listen` starts the socket front-end (TCP host:port or a
            // Unix-domain path) and serves until killed
            if let Some(spec) = args.opt("listen") {
                serve_listen(spec, workers, shards, pool_config);
                return;
            }
            // `--requests` is either a count (synthetic trace mode) or a
            // JSONL path / `-` for stdin (wire-protocol mode)
            let req_arg = args.opt("requests");
            if let Some(path) = req_arg.filter(|v| v.parse::<usize>().is_err()) {
                serve_jsonl(path, workers, shards, pool_config);
                return;
            }
            let n_req = req_arg.and_then(|v| v.parse().ok()).unwrap_or(24);
            let trace = build_trace(args.opt_str("trace", "mixed"), n_req);
            // the demo validates every response against the golden model;
            // --compare measures raw throughput, so validation is off there
            // unless explicitly requested
            let validate = if args.flag("compare") {
                args.flag("validate")
            } else {
                !args.flag("no-validate")
            };
            let quiet = args.flag("quiet") || args.flag("compare");
            // `--target tcpa|cgra|seq` pins every request to one backend —
            // how the sequential reference is served end to end
            let forced_target = args.opt("target").map(|t| {
                Target::parse(t).unwrap_or_else(|| {
                    eprintln!(
                        "unknown --target `{t}` (want one of: {})",
                        Target::ALL.map(|t| t.name()).join(", ")
                    );
                    std::process::exit(2);
                })
            });
            let trace: Vec<Request> = trace
                .into_iter()
                .map(|mut r| {
                    r.validate = validate;
                    if let Some(t) = forced_target {
                        r.target = t;
                    }
                    r
                })
                .collect();
            if args.flag("compare") {
                let (wall1, m1, r1) = run_trace(1, shards, &trace, true, pool_config.clone());
                let (walln, mn, rn) = run_trace(workers, shards, &trace, true, pool_config);
                let rps = |w: Duration| trace.len() as f64 / w.as_secs_f64().max(1e-9);
                println!("1 worker : {:?}  ({:.1} req/s)", wall1, rps(wall1));
                println!(
                    "{workers} workers: {:?}  ({:.1} req/s)  speedup {:.2}x",
                    walln,
                    rps(walln),
                    wall1.as_secs_f64() / walln.as_secs_f64().max(1e-9)
                );
                println!("1 worker : {}", m1.summary());
                println!("{workers} workers: {}", mn.report());
                // per-request cache outcome (`id:H` hit / `id:M`
                // miss-and-compile). Responses arrive in completion order,
                // which under N racing workers is nondeterministic — the
                // echoed ids are what keep the two listings comparable.
                println!("cache outcomes, 1 worker (completion order): {}", cache_outcomes(&r1));
                println!(
                    "cache outcomes, {workers} workers (completion order): {}",
                    cache_outcomes(&rn)
                );
            } else {
                let (wall, m, _) = run_trace(workers, shards, &trace, quiet, pool_config);
                println!(
                    "{} requests on {workers} workers in {wall:?} ({:.1} req/s)",
                    trace.len(),
                    trace.len() as f64 / wall.as_secs_f64().max(1e-9)
                );
                #[cfg(feature = "fault-injection")]
                let report = match &fault_plan {
                    Some(plan) => m.report_with_fault_plan(plan),
                    None => m.report(),
                };
                #[cfg(not(feature = "fault-injection"))]
                let report = m.report();
                println!("{report}");
            }
        }
        "analyze" => {
            let (names, n) = if args.flag("all") {
                (WorkloadCatalog::builtin().names(), args.opt_usize("n", 8) as i64)
            } else {
                let name = args.positional.get(1).cloned().unwrap_or_else(|| {
                    eprintln!("usage: repro analyze --all | repro analyze <name> <n>");
                    std::process::exit(2);
                });
                let n = args
                    .positional
                    .get(2)
                    .and_then(|v| v.parse::<i64>().ok())
                    .unwrap_or(8);
                (vec![name], n)
            };
            if !analyze(&names, n) {
                std::process::exit(1);
            }
        }
        "faults" => {
            let name = args.positional.get(1).cloned().unwrap_or_else(|| {
                eprintln!("usage: repro faults <name> <n> [--pe P] [--seed S]");
                std::process::exit(2);
            });
            let n = args
                .positional
                .get(2)
                .and_then(|v| v.parse::<i64>().ok())
                .unwrap_or(8);
            let pe = args.opt_usize("pe", 5);
            let seed = args.opt_u64("seed", 42);
            if !faults_report(&name, n, pe, seed) {
                std::process::exit(1);
            }
        }
        "lint" => {
            let root = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "src".to_string());
            match repro::analysis::lint::run(std::path::Path::new(&root)) {
                Ok(issues) if issues.is_empty() => {
                    println!("lint: clean ({root})");
                }
                Ok(issues) => {
                    for i in &issues {
                        eprintln!("{}", i.describe());
                    }
                    eprintln!("lint: {} issue(s)", issues.len());
                    std::process::exit(1);
                }
                Err(e) => {
                    eprintln!("lint failed: {e}");
                    std::process::exit(2);
                }
            }
        }
        "paula" => {
            let path = args.positional.get(1).expect("usage: repro paula <file>");
            let src = std::fs::read_to_string(path).expect("read paula file");
            let pra = paula::parse(&src).unwrap_or_else(|e| panic!("{e}"));
            let arch = TcpaArch::paper(
                args.opt_usize("width", 4),
                args.opt_usize("height", 4),
            );
            match compile(&pra, &arch) {
                Ok(cfg) => println!("{}", cfg.summary()),
                Err(e) => {
                    eprintln!("compile failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "all" => {
            println!("== Table I ==\n{}", harness::table1().render());
            let t2 = harness::table2(&BenchId::PAPER5, 4, 4, quick);
            println!("== Table II ==\n{}", t2.render());
            println!("== Table III ==\n{}", harness::table3().render());
            for id in BenchId::ALL {
                println!("== Fig. 6: {} ==", id.name());
                println!("{}", harness::fig6(id, &harness::fig6_sizes(id), quick).render());
            }
            println!("== Fig. 7 ==\n{}", harness::fig7(quick).render());
            println!("== Fig. 8 ==\n{}", harness::fig8(quick).render());
            println!("== ASIC ==\n{}", harness::asic_table().render());
        }
        _ => {
            eprintln!(
                "usage: repro <table1|table2|table3|fig6|fig7|fig8|asic|validate|serve|analyze|faults|lint|paula|all> \
                 [--quick] [--bench NAME] [--n N] [--sizes a,b,c] [--all] \
                 [--workers N] [--requests N|FILE.jsonl|-] [--trace mixed|NAME] \
                 [--listen ADDR|PATH] [--shards S] \
                 [--target tcpa|cgra|seq] [--compare] [--no-validate] \
                 [--queue-cap N] [--default-deadline-ms MS] \
                 [--fault-seed S] [--fault-rate R] [--pe P] [--seed S]"
            );
            std::process::exit(2);
        }
    }
}

/// Static legality verdict for every named workload at size `n`, per
/// registered backend (dispatched through the registry, never by target
/// case analysis), plus the size-independent symbolic TCPA proof. Returns
/// `false` when any hard verdict is ILLEGAL.
fn analyze(names: &[String], n: i64) -> bool {
    let catalog = WorkloadCatalog::builtin();
    let registry = repro::backend::BackendRegistry::with_defaults();
    let arch = TcpaArch::paper(4, 4);
    let mut all_legal = true;
    for name in names {
        let Some(spec) = catalog.spec(name, n) else {
            eprintln!(
                "unknown workload `{name}` (want one of: {})",
                catalog.names().join(", ")
            );
            return false;
        };
        let wl = spec.workload();
        println!("== {name} (n={n}) ==");
        for target in registry.targets() {
            let Some(backend) = registry.get(target) else {
                continue;
            };
            match backend.compile(&wl) {
                Ok(mapped) => match mapped.analysis() {
                    Some(rep) => {
                        println!("{}:\n{}", target.label(), rep.summary());
                        all_legal &= rep.is_legal();
                    }
                    None => println!(
                        "{}:\n  no static schedule (reference backend) — nothing to verify\n",
                        target.label()
                    ),
                },
                Err(e) => {
                    // a compile failure is not an illegality verdict: there
                    // is no mapping to verify
                    println!("{}:\n  compile failed at {}: {}\n", target.label(), e.stage, e.message);
                }
            }
        }
        let sym = repro::backend::tcpa::analyze_symbolic(&wl, &arch);
        for (kernel, rep) in &sym {
            println!("TCPA symbolic ({kernel}, all n):\n{}", rep.summary());
            all_legal &= rep.is_legal();
        }
    }
    if all_legal {
        println!("analyze: every mapping statically legal");
    } else {
        eprintln!("analyze: ILLEGAL mapping detected (see verdicts above)");
    }
    all_legal
}

/// Fault-plane drill for one workload on both array targets: serve it
/// healthy, serve it again under a fail-stop mask covering PE `pe`
/// (spare-aware remap — the backend recompiles around the dead PE and the
/// golden model re-validates the remapped outputs), then serve it DMR and
/// TMR under an armed per-PE SEU mask and report what the voters saw. The
/// session's merged fault counters close the loop. SEU strikes only fire
/// in chaos builds (`--features fault-injection`); elsewhere the legs run
/// clean and the vote passes trivially. Returns `false` when a served
/// response fails validation or errors unexpectedly.
fn faults_report(name: &str, n: i64, pe: usize, seed: u64) -> bool {
    use repro::coordinator::{Redundancy, Session};
    use repro::faults::FaultMask;
    if !WorkloadCatalog::builtin().contains(name) {
        eprintln!(
            "unknown workload `{name}` (want one of: {})",
            WorkloadCatalog::builtin().names().join(", ")
        );
        return false;
    }
    if !cfg!(feature = "fault-injection") {
        println!(
            "(plain build: SEU strikes disarmed — rebuild with \
             --features fault-injection to see DMR detect / TMR correct)"
        );
    }
    let mut all_ok = true;
    let mut merged = Metrics::default();
    for target in [Target::Tcpa, Target::Cgra] {
        println!("== {name} (n={n}) on {} ==", target.name());
        let mut session = Session::new();
        let mut id = 0u64;
        let mut next = |s: &mut Session, red: Redundancy| {
            id += 1;
            s.handle(&Request::named(id, name, n, target, 1, true, seed).with_redundancy(red))
        };
        let healthy = next(&mut session, Redundancy::None);
        match &healthy.error {
            None => println!(
                "  healthy:          latency={} cycles, validated={:?}",
                healthy.latency_cycles, healthy.validated
            ),
            Some(e) => {
                println!("  healthy:          FAILED: {e}");
                all_ok = false;
                continue;
            }
        }
        // spare-aware remap: fail one PE, recompile around it, re-validate
        session.set_fault_mask(target, FaultMask::healthy().with_failed_pe(pe));
        let masked = next(&mut session, Redundancy::None);
        match &masked.error {
            None => {
                let bitwise_ok = masked.validated == Some(true);
                println!(
                    "  fail-stop PE {pe}:   remapped, latency={} cycles, validated={:?}",
                    masked.latency_cycles, masked.validated
                );
                all_ok &= bitwise_ok;
            }
            // an honest verdict, not a failure of the drill: the surviving
            // sub-array may be too small for this workload size
            Some(e) => println!("  fail-stop PE {pe}:   unmappable on survivors: {e}"),
        }
        // redundant voting under an armed SEU mask (leg 0 is the armed leg)
        session.set_fault_mask(target, FaultMask::healthy().with_seu(1000, seed));
        for red in [Redundancy::Dmr, Redundancy::Tmr] {
            let voted = next(&mut session, red);
            match &voted.error {
                None => println!(
                    "  {}:              served, validated={:?}, fault_detected={}, corrected={}",
                    red.name(),
                    voted.validated,
                    voted.fault_detected,
                    voted.corrected
                ),
                Some(e) => println!("  {}:              withheld: {e}", red.name()),
            }
            all_ok &= voted.error.is_none() || red == Redundancy::Dmr;
        }
        merged.merge(&session.metrics);
    }
    println!(
        "faults: pe_faults={} remaps={} seu_injected={} seu_corrected={} vote_mismatches={}",
        merged.pe_faults,
        merged.remaps,
        merged.seu_injected,
        merged.seu_corrected,
        merged.vote_mismatches
    );
    all_ok
}

/// Serve the socket front-end until the process is killed: TCP
/// (`host:port` or `tcp:host:port`) or Unix-domain (`path` or `unix:path`)
/// listener over `shards` fingerprint-sharded cache pairs.
fn serve_listen(spec: &str, workers: usize, shards: usize, config: pool::PoolConfig) {
    let addr = repro::coordinator::ListenAddr::parse(spec);
    let server = repro::coordinator::net::serve_default(&addr, workers, shards, config)
        .unwrap_or_else(|e| {
            eprintln!("cannot listen on `{spec}`: {e}");
            std::process::exit(1);
        });
    eprintln!(
        "listening on {} ({workers} workers, {shards} shards)",
        server.local_addr()
    );
    let metrics = server.run();
    eprintln!("{}", metrics.report());
}

/// Serve newline-delimited JSON requests from a file (or stdin via `-`)
/// through the pool, writing JSON responses to stdout and the merged
/// metrics report to stderr (so piped output stays pure JSONL).
fn serve_jsonl(path: &str, workers: usize, shards: usize, config: pool::PoolConfig) {
    let stdin = std::io::stdin();
    let mut reader: Box<dyn std::io::BufRead> = if path == "-" {
        Box::new(stdin.lock())
    } else {
        let file = std::fs::File::open(path).unwrap_or_else(|e| {
            eprintln!("cannot open --requests `{path}`: {e}");
            std::process::exit(2);
        });
        Box::new(std::io::BufReader::new(file))
    };
    let catalog = std::sync::Arc::new(WorkloadCatalog::builtin());
    let metrics = wire::serve_jsonl_sharded(
        &mut reader,
        &mut std::io::stdout().lock(),
        workers,
        shards,
        catalog,
        config,
    )
    .unwrap_or_else(|e| {
        eprintln!("serve --requests failed: {e}");
        std::process::exit(1);
    });
    eprintln!("{}", metrics.report());
}

/// Build a request trace: `mixed` cycles through the whole builtin catalog,
/// both targets and several batch sizes; a workload name pins the kernel
/// and cycles targets/batches only. Unknown names are an error, not a
/// silent fallback to the mixed trace.
fn build_trace(kind: &str, n_req: usize) -> Vec<Request> {
    let catalog = WorkloadCatalog::builtin();
    let names: Vec<String> = if kind == "mixed" {
        catalog.names()
    } else if catalog.contains(kind) {
        vec![kind.to_string()]
    } else {
        eprintln!(
            "unknown --trace `{kind}` (want mixed or one of: {})",
            catalog.names().join(", ")
        );
        std::process::exit(2);
    };
    let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    Request::round_robin(&names, 8, n_req, 0)
}

/// Run a trace through [`pool::run_trace_sharded`], printing the responses
/// after the timed window so the req/s figure is not skewed by terminal I/O.
fn run_trace(
    workers: usize,
    shards: usize,
    trace: &[Request],
    quiet: bool,
    config: pool::PoolConfig,
) -> (Duration, Metrics, Vec<Response>) {
    let (wall, metrics, responses) = pool::run_trace_sharded(workers, shards, trace, config);
    if !quiet {
        for r in &responses {
            println!(
                "[{:>3}] {:<8} n={:<3} {:?} batch={} batch_cycles={} \
                 validated={:?} cache_hit={} exec_hit={} wall={:?}{}",
                r.id,
                r.workload,
                r.n,
                r.target,
                r.batch,
                r.batch_cycles,
                r.validated,
                r.cache_hit,
                r.exec_cache_hit,
                r.wall,
                r.error
                    .as_ref()
                    .map(|e| format!(" ERROR: {e}"))
                    .unwrap_or_default()
            );
        }
    }
    (wall, metrics, responses)
}

/// Compact per-request cache-outcome string (response completion order):
/// `id:E` when the whole report replayed from the exec cache, `id:H` when
/// the artifact came from the compile cache, `id:M` when this request
/// compiled it — the ids make the nondeterministic orderings of different
/// worker counts comparable.
fn cache_outcomes(responses: &[Response]) -> String {
    responses
        .iter()
        .map(|r| {
            let mark = if r.exec_cache_hit {
                'E'
            } else if r.cache_hit {
                'H'
            } else {
                'M'
            };
            format!("{}:{mark}", r.id)
        })
        .collect::<Vec<_>>()
        .join(" ")
}
