//! `repro` — the leader entrypoint: maps/simulates benchmarks and regenerates
//! every table and figure of the paper's evaluation.
//!
//! ```text
//! repro table1                 # qualitative toolchain features (Table I)
//! repro table2 [--quick]      # mapping results (Table II)
//! repro table3                 # FPGA resources + power (Table III)
//! repro fig6 [--bench gemm] [--sizes 8,12,16,20]
//! repro fig7 [--quick]        # speedups at the paper's sizes
//! repro fig8 [--quick]        # PE-count / unroll scaling incl. bounds
//! repro asic                   # §V-B2/§V-C2 published-chip comparison
//! repro validate [--bench gemm] [--n 8]   # end-to-end numeric validation
//! repro serve [--requests 16] # coordinator demo: batched invocations
//! repro paula <file.paula>    # compile a PAULA program onto the TCPA
//! repro all [--quick]         # everything above, in order
//! ```

use repro::bench::harness;
use repro::bench::workloads::BenchId;
use repro::coordinator::{Request, Session, Target};
use repro::ir::paula;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::compile;
use repro::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let quick = args.flag("quick");
    match cmd {
        "table1" => println!("{}", harness::table1().render()),
        "table2" => {
            let (t, _, _) = harness::table2(&BenchId::PAPER5, 4, 4, quick);
            println!("{}", t.render());
        }
        "table3" => println!("{}", harness::table3().render()),
        "fig6" => {
            let benches: Vec<BenchId> = match args.opt("bench") {
                Some(b) => vec![BenchId::parse(b).expect("unknown benchmark")],
                None => BenchId::ALL.to_vec(),
            };
            for id in benches {
                let sizes: Vec<i64> = match args.opt("sizes") {
                    Some(_) => args
                        .opt_usize_list("sizes", &[])
                        .into_iter()
                        .map(|x| x as i64)
                        .collect(),
                    None => harness::fig6_sizes(id),
                };
                println!("== Fig. 6: {} ==", id.name());
                println!("{}", harness::fig6(id, &sizes, quick).render());
            }
        }
        "fig7" => println!("{}", harness::fig7(quick).render()),
        "fig8" => println!("{}", harness::fig8(quick).render()),
        "asic" => println!("{}", harness::asic_table().render()),
        "validate" => {
            let benches: Vec<BenchId> = match args.opt("bench") {
                Some(b) => vec![BenchId::parse(b).expect("unknown benchmark")],
                None => BenchId::ALL.to_vec(),
            };
            let n = args.opt_usize("n", 8) as i64;
            for id in benches {
                match harness::validate(id, n, 42) {
                    Ok(lines) => {
                        println!("[ok] {} (N={n})", id.name());
                        for l in lines {
                            println!("     {l}");
                        }
                    }
                    Err(e) => {
                        eprintln!("[FAIL] {} (N={n}): {e}", id.name());
                        std::process::exit(1);
                    }
                }
            }
        }
        "serve" => {
            let n_req = args.opt_usize("requests", 12);
            let (tx, rx, handle) = Session::serve();
            let benches = [BenchId::Gemm, BenchId::Atax, BenchId::Gesummv];
            for i in 0..n_req {
                tx.send(Request {
                    bench: benches[i % benches.len()],
                    n: 8,
                    target: if i % 2 == 0 { Target::Tcpa } else { Target::Cgra },
                    batch: 1 + (i % 4) as u64,
                    validate: true,
                    seed: i as u64,
                })
                .unwrap();
            }
            for _ in 0..n_req {
                let r = rx.recv().unwrap();
                println!(
                    "{:<8} {:?} batch_cycles={} validated={:?} wall={:?}{}",
                    r.bench.name(),
                    r.target,
                    r.batch_cycles,
                    r.validated,
                    r.wall,
                    r.error.map(|e| format!(" ERROR: {e}")).unwrap_or_default()
                );
            }
            drop(tx);
            let m = handle.join().unwrap();
            println!("{}", m.summary());
        }
        "paula" => {
            let path = args.positional.get(1).expect("usage: repro paula <file>");
            let src = std::fs::read_to_string(path).expect("read paula file");
            let pra = paula::parse(&src).unwrap_or_else(|e| panic!("{e}"));
            let arch = TcpaArch::paper(
                args.opt_usize("width", 4),
                args.opt_usize("height", 4),
            );
            match compile(&pra, &arch) {
                Ok(cfg) => println!("{}", cfg.summary()),
                Err(e) => {
                    eprintln!("compile failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "all" => {
            println!("== Table I ==\n{}", harness::table1().render());
            let (t2, _, _) = harness::table2(&BenchId::PAPER5, 4, 4, quick);
            println!("== Table II ==\n{}", t2.render());
            println!("== Table III ==\n{}", harness::table3().render());
            for id in BenchId::ALL {
                println!("== Fig. 6: {} ==", id.name());
                println!("{}", harness::fig6(id, &harness::fig6_sizes(id), quick).render());
            }
            println!("== Fig. 7 ==\n{}", harness::fig7(quick).render());
            println!("== Fig. 8 ==\n{}", harness::fig8(quick).render());
            println!("== ASIC ==\n{}", harness::asic_table().render());
        }
        _ => {
            eprintln!(
                "usage: repro <table1|table2|table3|fig6|fig7|fig8|asic|validate|serve|paula|all> \
                 [--quick] [--bench NAME] [--n N] [--sizes a,b,c]"
            );
            std::process::exit(2);
        }
    }
}
