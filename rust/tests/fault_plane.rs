//! Integration suite for the hardware-fault plane (compiled only with
//! `--features fault-injection`; CI runs it as a dedicated tier-1 step).
//!
//! The acceptance oracle of the fault plane, end to end:
//!
//! * under a seeded fail-stop mask both array backends remap — the CGRA
//!   places and routes around the dead PE on the same grid, the TCPA
//!   re-tiles over the surviving sub-array — the static legality verifier
//!   passes on the *masked* architecture, and the remapped outputs are
//!   bit-identical to the healthy run;
//! * a fail-stop *detected* mid-execution is a health event: the session
//!   quarantines the reported PE, invalidates everything resident for the
//!   target, recompiles under the new mask and retries once — visible on
//!   the wire as `remapped` and in metrics as `remaps`;
//! * a seeded SEU corrupts exactly one leg of a redundant group: DMR
//!   detects (the mismatch is never served), TMR outvotes and serves a
//!   result bit-identical to the fault-free run — across the whole
//!   builtin catalog at one size;
//! * the merged counters reconcile *exactly* with the per-response wire
//!   fields: `remaps == Σ remapped`, `seu_corrected == Σ corrected`,
//!   `pe_faults + vote_mismatches == Σ fault_detected`.

#![cfg(feature = "fault-injection")]

use std::sync::Arc;

use repro::backend::{BackendRegistry, CancelToken, Target};
use repro::bench::spec::WorkloadCatalog;
use repro::coordinator::pool::{run_trace_configured, PoolConfig};
use repro::coordinator::{FaultPlan, FaultSite, Redundancy, Request, Response, Session};
use repro::faults::FaultMask;

const SEED: u64 = 42;

// ====================== spare-aware remap, backend level ===================

#[test]
fn masked_recompiles_pass_legality_and_match_healthy_outputs() {
    // gemm under a dead PE 5 on both array targets: the CGRA keeps its 4x4
    // geometry (operation-granular recovery), the TCPA re-tiles over the
    // surviving sub-array (iteration-granular) — both must stay statically
    // legal under the mask and reproduce the healthy outputs bit for bit
    let registry = BackendRegistry::with_defaults();
    let catalog = WorkloadCatalog::builtin();
    let cancel = CancelToken::none();
    let mask = FaultMask::healthy().with_failed_pe(5);
    for (target, n) in [(Target::Cgra, 8), (Target::Tcpa, 4)] {
        let backend = registry.get(target).expect("array backend registered");
        let spec = catalog.spec("gemm", n).expect("builtin");
        let wl = spec.workload();
        let healthy = backend.compile(&wl).expect("healthy gemm compiles");
        let masked = backend
            .compile_masked_cancellable(&wl, &mask, &cancel)
            .expect("gemm recompiles around the dead PE");
        let rep = masked
            .analysis()
            .expect("array backends attach a legality report");
        assert!(
            rep.is_legal(),
            "{target:?}: masked mapping must verify on the masked arch:\n{}",
            rep.summary()
        );
        let ins = spec.gen_inputs(SEED);
        let a = healthy.execute(&ins, 1).expect("healthy run");
        let b = masked.execute(&ins, 1).expect("masked run");
        assert_eq!(
            a.outputs, b.outputs,
            "{target:?}: spare-aware remap must be bit-identical to healthy"
        );
        assert_eq!(b.seu_flips, 0, "a structural mask injects nothing");
    }
}

// ================= detected fail-stop → quarantine + remap =================

fn wire_sums(responses: &[Response]) -> (u64, u64, u64) {
    let detected = responses.iter().filter(|r| r.fault_detected).count() as u64;
    let remapped = responses.iter().filter(|r| r.remapped).count() as u64;
    let corrected = responses.iter().filter(|r| r.corrected).count() as u64;
    (detected, remapped, corrected)
}

#[test]
fn pool_remaps_on_detected_fail_stops_and_reconciles_counters() {
    // a seeded fail-stop storm through the public pool API: every detection
    // must quarantine + remap at most once per request, remapped responses
    // that serve must still validate against the golden model, and the
    // merged counters must equal the wire-field sums exactly
    let plan = Arc::new(FaultPlan::new(23).with_rate(FaultSite::PeFailStop, 350));
    let config = PoolConfig {
        faults: Some(plan.clone()),
        ..PoolConfig::default()
    };
    let n_req = 40;
    // gemm n=4: small enough that even the TCPA's degraded 2x2 sub-array
    // (one quarantined PE retires a row and a column) still fits it
    let trace: Vec<Request> = (0..n_req)
        .map(|i| {
            let target = if i % 2 == 0 { Target::Tcpa } else { Target::Cgra };
            Request::named(i as u64, "gemm", 4, target, 1, true, i as u64)
        })
        .collect();
    let (_, m, responses) = run_trace_configured(2, &trace, config);
    assert_eq!(responses.len(), n_req, "one response per request");
    assert!(
        plan.injected(FaultSite::PeFailStop) > 0,
        "seed 23 at 350‰ over 40 requests must fire"
    );
    let (detected, remapped, _) = wire_sums(&responses);
    assert!(remapped > 0, "at least one detection must remap");
    let mut remapped_served = 0;
    for r in &responses {
        if r.remapped && r.error.is_none() {
            remapped_served += 1;
            assert_eq!(
                r.validated,
                Some(true),
                "request {}: remapped outputs must validate bit-exactly",
                r.id
            );
        }
    }
    assert!(remapped_served > 0, "some remapped request must serve");
    assert_eq!(m.remaps, remapped, "remaps == Σ remapped on the wire");
    assert_eq!(
        m.pe_faults + m.vote_mismatches,
        detected,
        "pe_faults + vote_mismatches == Σ fault_detected on the wire"
    );
    // the chaos plan's per-site injected counters ride along in the report
    let report = m.report_with_fault_plan(&plan);
    assert!(report.contains("injected: pe_fail_stop="), "{report}");
    assert!(report.contains("faults: pe_faults="), "{report}");
}

// ============== adversarial voting across the whole catalog ================

#[test]
fn dmr_detects_and_tmr_corrects_across_the_whole_catalog() {
    // every builtin benchmark at n=8 on both array targets, with the SEU
    // mask armed at 1000‰ (leg 0 of a redundant group is struck, the other
    // legs run clean — the single-event assumption): DMR must detect the
    // corrupted leg and never serve it; TMR must outvote it and serve a
    // result bit-identical to the fault-free run
    let catalog = WorkloadCatalog::builtin();
    for name in catalog.names() {
        for target in [Target::Tcpa, Target::Cgra] {
            let mut session = Session::new();
            let clean = session.handle(&Request::named(1, &name, 8, target, 1, true, SEED));
            assert!(
                clean.error.is_none(),
                "{name}/{target:?} fault-free: {:?}",
                clean.error
            );
            session.set_fault_mask(target, FaultMask::healthy().with_seu(1000, 1234));
            let dmr = session.handle(
                &Request::named(2, &name, 8, target, 1, true, SEED)
                    .with_redundancy(Redundancy::Dmr),
            );
            assert!(dmr.error.is_none(), "{name}/{target:?} DMR: {:?}", dmr.error);
            assert!(
                dmr.fault_detected,
                "{name}/{target:?}: DMR must detect the struck leg"
            );
            assert!(!dmr.corrected, "DMR detects, it does not correct");
            assert!(!dmr.remapped, "an SEU is transient: no remap");
            assert_eq!(
                dmr.validated,
                Some(true),
                "{name}/{target:?}: the corrupted DMR leg must never be served"
            );
            assert_eq!(session.metrics.vote_mismatches, 1);
            assert!(session.metrics.seu_injected > 0, "the strike must land");

            let tmr = session.handle(
                &Request::named(3, &name, 8, target, 1, true, SEED)
                    .with_redundancy(Redundancy::Tmr),
            );
            assert!(tmr.error.is_none(), "{name}/{target:?} TMR: {:?}", tmr.error);
            assert!(
                tmr.corrected,
                "{name}/{target:?}: TMR must outvote the struck leg"
            );
            assert!(
                !tmr.fault_detected,
                "a corrected strike is not a detection event"
            );
            assert_eq!(
                tmr.validated,
                Some(true),
                "{name}/{target:?}: the TMR majority must match the golden model"
            );
            assert_eq!(
                tmr.latency_cycles, clean.latency_cycles,
                "{name}/{target:?}: TMR serves a clean leg — identical report"
            );
            assert_eq!(session.metrics.seu_corrected, 1);
            assert_eq!(
                session.metrics.vote_mismatches, 1,
                "TMR correction must not count as a mismatch"
            );
        }
    }
}

// =================== counter ↔ wire-field reconciliation ===================

#[test]
fn fault_counters_reconcile_exactly_with_wire_fields() {
    // one session, three scenarios with disjoint counter signatures — an
    // injected fail-stop (remap), a DMR detection, a TMR correction — then
    // the exact reconciliation the Metrics::report identities promise
    let mut session = Session::new();
    let mut responses: Vec<Response> = Vec::new();

    // scenario 1: an injected fail-stop on the TCPA → quarantine + remap
    session.set_faults(Arc::new(
        FaultPlan::new(11).with_rate(FaultSite::PeFailStop, 1000),
    ));
    let r1 = session.handle(&Request::named(1, "gemm", 4, Target::Tcpa, 1, true, SEED));
    assert!(r1.error.is_none(), "{:?}", r1.error);
    assert!(r1.fault_detected && r1.remapped && !r1.corrected);
    assert_eq!(r1.validated, Some(true));
    responses.push(r1);

    // disarm the chaos plan; scenarios 2/3 use the SEU mask instead
    session.set_faults(Arc::new(FaultPlan::new(0)));
    session.set_fault_mask(Target::Cgra, FaultMask::healthy().with_seu(1000, 7));

    // scenario 2: DMR detection on the CGRA
    let r2 = session.handle(
        &Request::named(2, "gemm", 8, Target::Cgra, 1, true, SEED)
            .with_redundancy(Redundancy::Dmr),
    );
    assert!(r2.error.is_none(), "{:?}", r2.error);
    assert!(r2.fault_detected && !r2.remapped && !r2.corrected);
    responses.push(r2);

    // scenario 3: TMR correction on the CGRA
    let r3 = session.handle(
        &Request::named(3, "gemm", 8, Target::Cgra, 1, true, SEED)
            .with_redundancy(Redundancy::Tmr),
    );
    assert!(r3.error.is_none(), "{:?}", r3.error);
    assert!(r3.corrected && !r3.fault_detected && !r3.remapped);
    responses.push(r3);

    let (detected, remapped, corrected) = wire_sums(&responses);
    let m = &session.metrics;
    assert_eq!(m.pe_faults, 1);
    assert_eq!(m.remaps, remapped, "remaps == Σ remapped");
    assert_eq!(m.seu_corrected, corrected, "seu_corrected == Σ corrected");
    assert_eq!(
        m.pe_faults + m.vote_mismatches,
        detected,
        "pe_faults + vote_mismatches == Σ fault_detected"
    );
    assert!(m.seu_injected > 0, "strikes landed in the redundant legs");
    // the conditional report line surfaces all five counters at once
    let report = m.report();
    assert!(report.contains("faults: pe_faults=1"), "{report}");
}
