//! Property-based tests (hand-rolled generators on the deterministic
//! xorshift RNG — the vendored crate set has no proptest). Each property
//! runs across many random cases and checks a structural invariant of the
//! compilation pipelines.

use repro::cgra::arch::CgraArch;
use repro::cgra::mapper::{map, MapOpts};
use repro::frontend::dfg_gen::{generate, GenOpts};
use repro::frontend::mii;
use repro::frontend::transforms::unroll_innermost;
use repro::ir::affine::dot;
use repro::ir::loopnest::{idx, ArrayData, ArrayKind, Expr, LoopNest, NestBuilder};
use repro::ir::op::{Dtype, OpKind, Value};
use repro::ir::space::RectSpace;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::compile;
use repro::tcpa::partition::Partition;
use repro::util::rng::Rng;

/// Random rectangular nest: 1–3 dims, small extents, a reduction-flavored
/// body over 2 input arrays and 1 in-out array.
fn random_nest(rng: &mut Rng) -> LoopNest {
    let depth = 1 + rng.below(3);
    let extents: Vec<i64> = (0..depth).map(|_| 2 + rng.below(3) as i64).collect();
    let out_dims = 1 + rng.below(depth.min(2));
    let out_shape: Vec<i64> = extents[..out_dims].to_vec();
    let mut b = NestBuilder::new("rand", Dtype::I32);
    for (k, &e) in extents.iter().enumerate() {
        b = b.dim(&format!("i{k}"), e);
    }
    b = b
        .array("X", extents.clone(), ArrayKind::Input)
        .array("Y", extents.clone(), ArrayKind::Input)
        .array("O", out_shape, ArrayKind::InOut);
    let full_idx: Vec<_> = (0..depth).map(|k| idx(depth, k)).collect();
    let out_idx: Vec<_> = (0..out_dims).map(|k| idx(depth, k)).collect();
    let op = *rng.choose(&[OpKind::Add, OpKind::Sub, OpKind::Mul]);
    let inner = Expr::bin(
        op,
        Expr::read(0, full_idx.clone()),
        Expr::read(1, full_idx),
    );
    let body = Expr::bin(OpKind::Add, Expr::read(2, out_idx.clone()), inner);
    b.stmt("O", out_idx, body).finish()
}

fn random_inputs(rng: &mut Rng, nest: &LoopNest) -> ArrayData {
    let mut m = ArrayData::new();
    for a in &nest.arrays {
        m.insert(
            a.name.clone(),
            (0..a.len())
                .map(|_| Value::I32(rng.range_i64(-9, 10) as i32))
                .collect(),
        );
    }
    m
}

#[test]
fn prop_dfg_generation_preserves_semantics() {
    let mut rng = Rng::new(0xD0D0);
    for case in 0..60 {
        let nest = random_nest(&mut rng);
        let ins = random_inputs(&mut rng, &nest);
        let want = nest.execute(&ins);
        for opts in [GenOpts::flat(), GenOpts::naive()] {
            let gen = generate(&nest, &opts).expect("dfg gen");
            let got = gen.dfg.execute(&ins);
            assert_eq!(got["O"], want["O"], "case {case}: {:?}", opts);
        }
    }
}

#[test]
fn prop_unroll_preserves_semantics() {
    let mut rng = Rng::new(0xBEE);
    for case in 0..40 {
        let nest = random_nest(&mut rng);
        // only even innermost extents are unrollable by 2 (bumping the
        // extent would read outside the generated arrays)
        let d = nest.depth();
        if nest.dims[d - 1].extent.c % 2 != 0 {
            continue;
        }
        let ins = random_inputs(&mut rng, &nest);
        let want = nest.execute(&ins);
        let un = unroll_innermost(&nest, 2).expect("unroll");
        assert_eq!(un.execute(&ins)["O"], want["O"], "case {case} (nest)");
        let gen = generate(&un, &GenOpts::flat()).expect("dfg");
        assert_eq!(gen.dfg.execute(&ins)["O"], want["O"], "case {case} (dfg)");
    }
}

#[test]
fn prop_mapping_respects_all_dependences() {
    let mut rng = Rng::new(0xAB);
    let arch = CgraArch::classical(4, 4);
    for case in 0..12 {
        let nest = random_nest(&mut rng);
        let gen = generate(&nest, &GenOpts::flat()).expect("dfg");
        let opts = MapOpts {
            seed: case,
            ..MapOpts::negotiated()
        };
        let m = match map(&gen.dfg, &arch, &gen.inter_iteration_hazards, &opts) {
            Ok(m) => m,
            Err(e) => panic!("case {case}: mapping failed: {e}"),
        };
        // every dependence satisfied: τ_src + lat ≤ τ_dst + II·dist
        for (s, d, dist) in gen.dfg.sched_deps() {
            let lhs = m.tau[s] as i64 + gen.dfg.nodes[s].kind.latency() as i64;
            let rhs = m.tau[d] as i64 + (m.ii as i64) * dist as i64;
            assert!(lhs <= rhs, "case {case}: dep ({s}->{d},{dist})");
        }
        // every route has exactly the slack it claims
        for rp in &m.routes {
            assert_eq!(rp.path.len() as i64 - 1, rp.slack, "case {case}");
        }
        // achieved II is at least the analytic lower bound
        let lb = mii::mii(
            &gen.dfg,
            &gen.inter_iteration_hazards,
            arch.n_pes(),
            arch.mem_pes().len(),
        );
        assert!(m.ii >= lb, "case {case}: II {} < bound {lb}", m.ii);
    }
}

#[test]
fn prop_partition_is_exact_cover() {
    let mut rng = Rng::new(0x51);
    for _ in 0..40 {
        let dims = 1 + rng.below(3);
        let w = 1 + rng.below(4);
        let h = 1 + rng.below(4);
        let extents: Vec<i64> = (0..dims)
            .map(|k| {
                let grid = if k == 0 { h as i64 } else if k == 1 { w as i64 } else { 1 };
                grid * (1 + rng.below(4) as i64)
            })
            .collect();
        let pra = repro::ir::pra::PraBuilder::new("p", Dtype::I32, extents.clone())
            .var("x")
            .eq(
                "e",
                "x",
                OpKind::Mov,
                vec![repro::ir::pra::Arg::Const(1)],
                repro::ir::space::CondSpace::all(),
            )
            .finish();
        let arch = TcpaArch::paper(w, h);
        let part = match Partition::lsgp(&pra, &arch) {
            Ok(p) => p,
            Err(e) => panic!("partition failed for {extents:?} on {w}x{h}: {e}"),
        };
        // decompose∘global == identity and the tiles cover the space exactly
        let space = RectSpace::new(extents);
        let mut count = 0u64;
        for i in space.points() {
            let (k, j) = part.decompose(&i);
            assert!(part.inter.contains(&k));
            assert!(part.intra.contains(&j));
            assert_eq!(part.global(&k, &j), i);
            count += 1;
        }
        assert_eq!(count, part.n_tiles() * part.iterations_per_pe());
    }
}

#[test]
fn prop_tcpa_schedule_satisfies_dependences() {
    let mut rng = Rng::new(0x77);
    use repro::bench::workloads::{build, BenchId};
    for _ in 0..10 {
        let id = *rng.choose(&BenchId::ALL.as_slice());
        let n = 8;
        let wl = build(id, n);
        let arch = TcpaArch::paper(4, 4);
        for pra in &wl.pras {
            let cfg = compile(pra, &arch).expect("compile");
            for dep in pra.dependences() {
                let lat = pra.eqs[dep.from].op.latency() as i64;
                let lhs = cfg.sched.tau[dep.from] as i64 + lat;
                let rhs =
                    dot(&cfg.sched.lambda_j, &dep.d) + cfg.sched.tau[dep.to] as i64;
                if dep.d.iter().all(|&x| x == 0) {
                    if dep.from != dep.to {
                        assert!(lhs <= rhs, "{}: intra dep {:?}", id.name(), dep);
                    }
                } else {
                    assert!(lhs <= rhs, "{}: dep {:?}", id.name(), dep);
                }
            }
        }
    }
}

#[test]
fn prop_simulated_latency_equals_closed_form() {
    use repro::bench::workloads::{build, inputs, BenchId};
    use repro::tcpa::sim::simulate;
    let mut rng = Rng::new(0x99);
    for _ in 0..6 {
        let id = *rng.choose(&[BenchId::Gemm, BenchId::Gesummv, BenchId::Trisolv].as_slice());
        let wl = build(id, 8);
        let arch = TcpaArch::paper(4, 4);
        let cfg = compile(&wl.pras[0], &arch).unwrap();
        let r = simulate(&cfg, &arch, &inputs(id, 8, rng.next_u64())).unwrap();
        // the closed form is an upper bound tight to within one iteration's
        // schedule length: the final iterations of a tile need not activate
        // the latest-scheduled equation (condition spaces)
        let slack = cfg.sched.iter_len as u64;
        assert!(
            r.cycles <= cfg.last_pe_latency() && r.cycles + slack >= cfg.last_pe_latency(),
            "{}: sim {} vs closed {}",
            id.name(),
            r.cycles,
            cfg.last_pe_latency()
        );
        // triangular problems leave whole tiles with no active equations
        // (e.g. TRISOLV's strict upper triangle), so compare the earliest
        // *busy* PE against the closed form
        let first_busy = r
            .per_pe_done
            .iter()
            .copied()
            .filter(|&c| c > 0)
            .min()
            .unwrap_or(0);
        assert!(
            first_busy <= cfg.first_pe_latency()
                && first_busy + slack >= cfg.first_pe_latency().min(first_busy + slack),
            "{}: first {} vs closed {}",
            id.name(),
            first_busy,
            cfg.first_pe_latency()
        );
    }
}

#[test]
fn prop_paula_roundtrip_random_conditions() {
    // random 2-D PRAs written as PAULA text parse back to the same semantics
    let mut rng = Rng::new(0x42);
    for case in 0..20 {
        let n = 3 + rng.below(4) as i64;
        let c = rng.range_i64(0, n);
        let src = format!(
            "program p{case}\ndtype i32\nspace {n} {n}\nvar x\nvar y\n\
             input A {n} {n}\noutput B {n} {n}\n\
             eq E1: x[i] = A[i0, i1]\n\
             eq E2: y[i] = x[i] + 1 if i0 >= {c}\n\
             eq E2b: y[i] = x[i] if i0 < {c}\n\
             eq E3: B[i0, i1] = y[i]\n"
        );
        let pra = repro::ir::paula::parse(&src).expect("parse");
        let mut ins = ArrayData::new();
        ins.insert(
            "A".into(),
            (0..(n * n) as usize)
                .map(|i| Value::I32(i as i32))
                .collect(),
        );
        let out = pra.execute(&ins);
        for i0 in 0..n {
            for i1 in 0..n {
                let base = (i0 * n + i1) as i32;
                let want = if i0 >= c { base + 1 } else { base };
                assert_eq!(
                    out["B"][(i0 * n + i1) as usize],
                    Value::I32(want),
                    "case {case} at ({i0},{i1})"
                );
            }
        }
    }
}
