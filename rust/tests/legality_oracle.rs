//! The agreement oracle for the static legality verifier (`analysis`):
//!
//! 1. **Legal leg** — every artifact the compilers produce must verify
//!    legal, and the cycle-accurate simulators must agree: zero timing
//!    violations, measured queue occupancy within the declared depths.
//! 2. **Adversarial leg** — seeded mutations of λ, τ, II and FIFO depths
//!    must be rejected by the verifier with the offending dependence edge
//!    named, and the verdict's `observable` model must agree *exactly*
//!    with the simulators' violation counters
//!    (`runtime_legal() ⇔ counters == 0`). Counter-silent breakage
//!    (RD-bound early reads, shallow FIFOs over unbounded sim queues) is
//!    caught by the other two oracles: output correctness against the PRA
//!    reference interpreter, and measured occupancy.
//! 3. **Symbolic leg** — one n-independent proof covers every
//!    instantiation with no per-size re-verification, and a poisoned
//!    candidate is rejected by the proof while slipping through
//!    `instantiate` (which re-checks only `d ≠ 0`) — exactly the gap the
//!    static verifier exists to close.

use repro::analysis::{verify_cgra, verify_symbolic, verify_tcpa_config, Rule};
use repro::bench::workloads::{build, inputs, BenchId};
use repro::cgra::arch::CgraArch;
use repro::cgra::mapper::{map, MapOpts};
use repro::cgra::sim as cgra_sim;
use repro::frontend::dfg_gen::{generate, GenOpts};
use repro::ir::affine::dot;
use repro::ir::loopnest::ArrayData;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::{compile, compile_with, TcpaConfig};
use repro::tcpa::registers::RegKind;
use repro::tcpa::schedule::{alternative_groups, schedule_symbolic};
use repro::tcpa::sim::{simulate, simulate_workload};

const SIZES: [i64; 3] = [8, 12, 16];
const SEED: u64 = 42;

/// Deepest FD FIFO the binding declares (top-level and channel-interior).
fn max_declared_fd(cfg: &TcpaConfig) -> usize {
    cfg.binding
        .sinks
        .iter()
        .map(|s| match &s.kind {
            RegKind::Fd { depth, .. } => *depth,
            RegKind::Channel { intra, .. } => match intra.as_ref() {
                RegKind::Fd { depth, .. } => *depth,
                _ => 0,
            },
            RegKind::Rd { .. } => 0,
        })
        .max()
        .unwrap_or(0)
}

/// Recompute `iter_len` after a τ mutation (the scheduler's invariant).
fn fix_iter_len(cfg: &mut TcpaConfig) {
    cfg.sched.iter_len = cfg
        .pra
        .eqs
        .iter()
        .enumerate()
        .map(|(e, eq)| cfg.sched.tau[e] + eq.op.latency())
        .max()
        .unwrap_or(0);
}

// ===================== 1. legal leg =========================================

/// Every compiled TCPA kernel verifies legal, and the simulator agrees on
/// all three runtime oracles: timing counters zero, FD occupancy within
/// the declared depths, outputs matching the PRA reference interpreter.
#[test]
fn tcpa_compiled_artifacts_verify_legal_and_sim_agrees() {
    let arch = TcpaArch::paper(4, 4);
    let mut checked = 0usize;
    for id in BenchId::ALL {
        for n in SIZES {
            let wl = build(id, n);
            let ins = inputs(id, n, SEED);
            let cfgs: Vec<TcpaConfig> = wl
                .pras
                .iter()
                .map(|p| compile(p, &arch).unwrap_or_else(|e| panic!("{}: {e}", p.name)))
                .collect();
            for cfg in &cfgs {
                let rep = verify_tcpa_config(cfg, &arch, &cfg.pra.name);
                assert!(rep.is_legal(), "{} n={n}:\n{}", cfg.pra.name, rep.summary());
                assert!(rep.runtime_legal(), "{} n={n}:\n{}", cfg.pra.name, rep.summary());
                assert!(rep.n_deps > 0, "{} n={n}: no deps examined", cfg.pra.name);
                checked += 1;
            }
            let run = simulate_workload(&cfgs, &arch, &ins).expect("io");
            for (cfg, k) in cfgs.iter().zip(&run.kernels) {
                assert_eq!(
                    k.timing_violations, 0,
                    "{} n={n}: sim disagrees with the static LEGAL verdict",
                    cfg.pra.name
                );
                assert!(
                    k.max_fd_occupancy <= max_declared_fd(cfg),
                    "{} n={n}: occupancy {} exceeds declared FD depth {}",
                    cfg.pra.name,
                    k.max_fd_occupancy,
                    max_declared_fd(cfg)
                );
            }
        }
    }
    assert!(checked >= 15, "only {checked} kernels checked");
}

/// Every mapped CGRA stage verifies legal and the simulator counts zero
/// hazards on it.
#[test]
fn cgra_mapped_stages_verify_legal_and_sim_agrees() {
    let arch = CgraArch::classical(4, 4);
    let opts = MapOpts::negotiated();
    let mut checked = 0usize;
    for id in BenchId::ALL {
        let wl = build(id, 8);
        let mut ins = inputs(id, 8, SEED);
        for nest in &wl.stages {
            let gen = generate(nest, &GenOpts::flat()).expect("generate");
            let m = map(&gen.dfg, &arch, &gen.inter_iteration_hazards, &opts)
                .unwrap_or_else(|e| panic!("{}: {e}", gen.dfg.name));
            let rep = verify_cgra(
                &gen.dfg,
                &m,
                &gen.inter_iteration_hazards,
                arch.n_pes(),
                arch.mem_pes().len(),
                &gen.dfg.name,
            );
            assert!(rep.is_legal(), "{}:\n{}", gen.dfg.name, rep.summary());
            assert!(rep.runtime_legal(), "{}:\n{}", gen.dfg.name, rep.summary());
            assert!(rep.stages[0].min_ii <= rep.stages[0].achieved_ii);
            let r = cgra_sim::simulate(&gen.dfg, &m, &ins);
            assert_eq!(
                r.timing_hazards, 0,
                "{}: sim disagrees with the static LEGAL verdict",
                gen.dfg.name
            );
            // chain stage outputs into the next stage's inputs
            ins.extend(r.outputs);
            checked += 1;
        }
    }
    assert!(checked >= 6, "only {checked} stages checked");
}

// ===================== 2. adversarial leg (TCPA) ============================

/// A producer pushed one cycle past its queue-bound inter-iteration
/// consumer: the intra-tile inequality breaks, the edge is named, and the
/// simulator's violation counter agrees.
#[test]
fn tcpa_tau_mutant_rejected_and_counted() {
    let arch = TcpaArch::paper(4, 4);
    let wl = build(BenchId::Gemm, 8);
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let base = compile(&wl.pras[0], &arch).expect("compile");
    assert_eq!(
        simulate(&base, &arch, &ins).expect("io").timing_violations,
        0
    );

    let mut cfg = base.clone();
    // A d ≠ 0 dependence with (i) a queue-bound sink, so the late value
    // moves through a FIFO the counter watches, and (ii) an instance that
    // stays inside one tile, so the λʲ slack is actually exercised.
    let dep = cfg
        .pra
        .dependences()
        .into_iter()
        .find(|dep| {
            !dep.is_intra_iteration()
                && dep.d.iter().zip(&cfg.part.tile).all(|(&x, &t)| x < t)
                && cfg.binding.sinks.iter().any(|s| {
                    s.var == dep.var
                        && s.d == dep.d
                        && s.to_eq == dep.to
                        && !matches!(s.kind, RegKind::Rd { .. })
                })
        })
        .expect("gemm has a queue-bound local inter-iteration dep");
    let lat = cfg.pra.eqs[dep.from].op.latency() as i64;
    let slack = dot(&cfg.sched.lambda_j, &dep.d) + cfg.sched.tau[dep.to] as i64
        - (cfg.sched.tau[dep.from] as i64 + lat);
    assert!(slack >= 0, "compiled schedule violates its own inequality");
    // Keep the original binding: rebinding would re-derive FIFO depths
    // around the mutation and could silently re-legalize it.
    cfg.sched.tau[dep.from] += slack as u32 + 1;
    fix_iter_len(&mut cfg);

    let rep = verify_tcpa_config(&cfg, &arch, "tau-mutant");
    assert!(!rep.is_legal(), "mutant accepted:\n{}", rep.summary());
    assert!(
        rep.violations
            .iter()
            .any(|v| v.edge.from == dep.from && v.edge.to == dep.to),
        "offending edge not named:\n{}",
        rep.summary()
    );
    let r = simulate(&cfg, &arch, &ins).expect("io");
    assert!(r.timing_violations > 0, "sim missed the seeded hazard");
    assert_eq!(rep.runtime_legal(), r.timing_violations == 0);
}

/// A wavefront offset decremented below the tight bound: the λᵏ
/// inequality breaks and the boundary word arrives late on the channel.
#[test]
fn tcpa_lambda_k_mutant_rejected_and_counted() {
    let arch = TcpaArch::paper(4, 4);
    let wl = build(BenchId::Gemm, 8);
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let mut cfg = compile(&wl.pras[0], &arch).expect("compile");
    // realize() sets λᵏ_m to exactly the max need over crossing deps, so
    // any positive component is tight and −1 must violate.
    let m = cfg
        .sched
        .lambda_k
        .iter()
        .position(|&l| l > 0)
        .expect("gemm on 4x4 has a tile-crossing dimension");
    cfg.sched.lambda_k[m] -= 1;

    let rep = verify_tcpa_config(&cfg, &arch, "lambda-k-mutant");
    assert!(!rep.is_legal(), "mutant accepted:\n{}", rep.summary());
    assert!(
        rep.violations.iter().any(|v| v.rule == Rule::Wavefront),
        "wavefront rule not flagged:\n{}",
        rep.summary()
    );
    let r = simulate(&cfg, &arch, &ins).expect("io");
    assert!(r.timing_violations > 0, "sim missed the late channel word");
    assert_eq!(rep.runtime_legal(), r.timing_violations == 0);
}

/// FD FIFOs shrunk below the binder's in-flight window: statically
/// illegal, *counter-silent* (the simulator's queues are unbounded), and
/// caught at runtime only by the occupancy measurement — the case that
/// motivates a static verifier in the first place.
#[test]
fn tcpa_fifo_mutant_rejected_counter_silent_occupancy_caught() {
    let arch = TcpaArch::paper(4, 4);
    // Find a kernel whose baseline occupancy leaves room to shrink while
    // keeping every depth >= 1 (the plan lowering's invariant).
    let (id, cfg0, occ) = BenchId::ALL
        .iter()
        .find_map(|&id| {
            let wl = build(id, 8);
            let cfg = compile(&wl.pras[0], &arch).ok()?;
            let r = simulate(&cfg, &arch, &inputs(id, 8, SEED)).ok()?;
            (r.max_fd_occupancy >= 2).then_some((id, cfg, r.max_fd_occupancy))
        })
        .expect("some benchmark reaches FD occupancy >= 2");
    let ins = inputs(id, 8, SEED);

    let mut cfg = cfg0.clone();
    let target = occ - 1;
    let mut shrunk = false;
    for s in &mut cfg.binding.sinks {
        let depth = match &mut s.kind {
            RegKind::Fd { depth, .. } => Some(depth),
            RegKind::Channel { intra, .. } => match intra.as_mut() {
                RegKind::Fd { depth, .. } => Some(depth),
                _ => None,
            },
            RegKind::Rd { .. } => None,
        };
        if let Some(depth) = depth {
            if *depth > target {
                *depth = target;
                shrunk = true;
            }
        }
    }
    assert!(shrunk, "occupancy {occ} implies some FIFO deeper than {target}");

    let rep = verify_tcpa_config(&cfg, &arch, "fifo-mutant");
    assert!(!rep.is_legal(), "mutant accepted:\n{}", rep.summary());
    let fifo_viol = rep
        .violations
        .iter()
        .find(|v| v.rule == Rule::FifoDepth)
        .expect("fifo-depth rule flagged");
    assert!(!fifo_viol.observable, "unbounded queues cannot underflow");

    let r = simulate(&cfg, &arch, &ins).expect("io");
    assert_eq!(r.timing_violations, 0, "shallow FIFOs are counter-silent");
    assert_eq!(rep.runtime_legal(), r.timing_violations == 0);
    assert!(
        r.max_fd_occupancy > target,
        "occupancy oracle must catch what the counter cannot"
    );
}

/// II bumped with λʲ recomputed but λᵏ left stale: the wavefront need
/// grows with λʲ, so the stale offsets are now too small — rejected
/// statically, counted at runtime.
#[test]
fn tcpa_ii_mutant_with_stale_wavefront_rejected_and_counted() {
    let arch = TcpaArch::paper(4, 4);
    let wl = build(BenchId::Gemm, 8);
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let mut cfg = compile(&wl.pras[0], &arch).expect("compile");
    assert!(
        cfg.sched.lambda_k.iter().any(|&l| l > 0),
        "needs a crossing dim"
    );
    cfg.sched.ii += 1;
    // λʲ must stay the lexicographic tile scan of the new II (the plan
    // lowering asserts exactly this); λᵏ is deliberately left stale.
    let mut stride = cfg.sched.ii as i64;
    for k in (0..cfg.part.tile.len()).rev() {
        cfg.sched.lambda_j[k] = stride;
        stride *= cfg.part.tile[k];
    }

    let rep = verify_tcpa_config(&cfg, &arch, "ii-mutant");
    assert!(!rep.is_legal(), "mutant accepted:\n{}", rep.summary());
    assert!(
        rep.violations.iter().any(|v| v.rule == Rule::Wavefront),
        "stale wavefront not flagged:\n{}",
        rep.summary()
    );
    let r = simulate(&cfg, &arch, &ins).expect("io");
    assert!(r.timing_violations > 0, "sim missed the stale wavefront");
    assert_eq!(rep.runtime_legal(), r.timing_violations == 0);
}

/// Benign mutations — extra wavefront slack, deeper FIFOs — must NOT be
/// rejected (no false positives), and the simulator stays clean on them.
#[test]
fn tcpa_benign_mutants_stay_legal() {
    let arch = TcpaArch::paper(4, 4);
    let wl = build(BenchId::Gemm, 8);
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let base = compile(&wl.pras[0], &arch).expect("compile");
    let base_out = simulate(&base, &arch, &ins).expect("io").outputs;

    // extra wavefront slack: later tile starts, same values
    let mut slow = base.clone();
    for l in slow.sched.lambda_k.iter_mut() {
        *l += 5;
    }
    let rep = verify_tcpa_config(&slow, &arch, "benign-lambda-k");
    assert!(rep.is_legal(), "false positive:\n{}", rep.summary());
    let r = simulate(&slow, &arch, &ins).expect("io");
    assert_eq!(r.timing_violations, 0);
    assert_eq!(rep.runtime_legal(), r.timing_violations == 0);
    assert_eq!(r.outputs, base_out, "extra slack changed values");

    // deeper FIFOs: strictly more headroom
    let mut deep = base.clone();
    for s in &mut deep.binding.sinks {
        if let RegKind::Fd { depth, .. } = &mut s.kind {
            *depth += 3;
        }
    }
    let rep = verify_tcpa_config(&deep, &arch, "benign-fd");
    assert!(rep.is_legal(), "false positive:\n{}", rep.summary());
    let r = simulate(&deep, &arch, &ins).expect("io");
    assert_eq!(r.timing_violations, 0);
    assert_eq!(r.outputs, base_out, "deeper FIFOs changed values");
}

// ===================== 2. adversarial leg (CGRA) ============================

/// A CGRA producer delayed onto its consumer's issue cycle: the flow
/// inequality breaks in the counter-observable window (producer sequenced
/// first in the (τ, v) slot order), the edge is named, and the simulator's
/// hazard counter agrees. The sibling benign bump (exactly the available
/// slack) must stay legal and hazard-free with identical outputs.
#[test]
fn cgra_tau_mutants_agree_with_hazard_counter() {
    let arch = CgraArch::classical(4, 4);
    let opts = MapOpts::negotiated();
    let wl = build(BenchId::Gemm, 8);
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let gen = generate(&wl.stages[0], &GenOpts::flat()).expect("generate");
    let hz = &gen.inter_iteration_hazards;
    let m = map(&gen.dfg, &arch, hz, &opts).expect("map");
    let base = cgra_sim::simulate(&gen.dfg, &m, &ins);
    assert_eq!(base.timing_hazards, 0);

    // ---- illegal: land the producer on the consumer's cycle ----
    // A same-iteration edge with src < dst issues the producer first in
    // the (τ, v)-sorted slot when their cycles collide, so the late read
    // is deterministically counter-visible.
    let edge = gen
        .dfg
        .edges()
        .iter()
        .find(|e| e.dist == 0 && e.src < e.dst)
        .cloned()
        .expect("gemm DFG has a forward same-iteration edge");
    let lat = gen.dfg.nodes[edge.src].kind.latency();
    let slack = m.tau[edge.dst] - m.tau[edge.src] - lat;
    let mut m2 = m.clone();
    m2.tau[edge.src] += slack + lat; // τ(src) = τ(dst): violation = latency
    m2.sched_len = m2.sched_len.max(m2.tau[edge.src] + lat);
    let rep = verify_cgra(
        &gen.dfg,
        &m2,
        hz,
        arch.n_pes(),
        arch.mem_pes().len(),
        "cgra-tau-mutant",
    );
    assert!(!rep.is_legal(), "mutant accepted:\n{}", rep.summary());
    assert!(
        rep.violations
            .iter()
            .any(|v| v.edge.from == edge.src && v.edge.to == edge.dst && v.observable),
        "offending edge not named observable:\n{}",
        rep.summary()
    );
    let r = cgra_sim::simulate(&gen.dfg, &m2, &ins);
    assert!(r.timing_hazards > 0, "sim missed the seeded hazard");
    assert_eq!(rep.runtime_legal(), r.timing_hazards == 0);

    // ---- benign: consume exactly the minimum slack of some node ----
    let edges = repro::analysis::dfg_dep_edges(&gen.dfg, hz);
    let (src, min_slack) = (0..gen.dfg.n_nodes())
        .find_map(|v| {
            let s = edges
                .iter()
                .filter(|e| e.from == v)
                .map(|e| {
                    m.tau[e.to] as i64 + m.ii as i64 * e.d[0] - (m.tau[v] as i64 + e.latency)
                })
                .min()?;
            (s >= 1).then_some((v, s))
        })
        .expect("some node has positive outgoing slack");
    let mut m3 = m.clone();
    m3.tau[src] += min_slack as u32;
    m3.sched_len = m3
        .sched_len
        .max(m3.tau[src] + gen.dfg.nodes[src].kind.latency());
    let rep = verify_cgra(
        &gen.dfg,
        &m3,
        hz,
        arch.n_pes(),
        arch.mem_pes().len(),
        "cgra-benign",
    );
    assert!(rep.is_legal(), "false positive:\n{}", rep.summary());
    let r = cgra_sim::simulate(&gen.dfg, &m3, &ins);
    assert_eq!(r.timing_hazards, 0);
    assert_eq!(r.outputs, base.outputs, "slack-only shift changed values");
}

// ===================== 3. symbolic leg ======================================

/// One symbolic proof covers every instantiation: verify once per shape,
/// then instantiate at several sizes with *no* per-n re-verification and
/// confirm the simulator and the PRA reference agree at each.
#[test]
fn symbolic_proof_covers_all_instantiations() {
    let arch = TcpaArch::paper(4, 4);
    let shape = build(BenchId::Gemm, 8);
    let sym = schedule_symbolic(&shape.pras[0], &arch);
    // the ONE verification for this kernel shape
    let rep = verify_symbolic(&shape.pras[0], &sym);
    assert!(rep.is_legal(), "{}", rep.summary());
    assert!(rep.proven_ii.is_some(), "{}", rep.summary());

    for n in SIZES {
        // deliberately no verify_* call in this loop — the symbolic proof
        // above already covers this instantiation
        let wl = build(BenchId::Gemm, n);
        let ins = inputs(BenchId::Gemm, n, SEED);
        let cfg = compile_with(&wl.pras[0], &arch, &sym).expect("instantiate");
        let r = simulate(&cfg, &arch, &ins).expect("io");
        assert_eq!(r.timing_violations, 0, "n={n}");
        let golden = wl.pras[0].execute(&ins);
        for (name, vals) in &r.outputs {
            assert_eq!(golden.get(name), Some(vals), "n={n} array {name}");
        }
    }
}

/// A poisoned symbolic candidate (a producer scheduled after its
/// zero-distance consumer) is rejected by the shape proof with the edge
/// named — while `instantiate` accepts it (it re-checks only `d ≠ 0`) and
/// the simulator's counter stays silent (the value is RD-bound). Only the
/// output oracle catches it at runtime; the static proof catches it
/// before anything runs.
#[test]
fn symbolic_mutant_rejected_by_proof_but_silent_at_runtime() {
    let arch = TcpaArch::paper(4, 4);
    let wl = build(BenchId::Gemm, 8);
    let pra = &wl.pras[0];
    let ins = inputs(BenchId::Gemm, 8, SEED);
    let deps = pra.dependences();
    let (group_of, _) = alternative_groups(pra);

    // A cross-group d = 0 dependence whose producer has no d ≠ 0 uses:
    // mutating its τ breaks only the intra-iteration ordering, which
    // instantiate() never re-checks.
    let dep = deps
        .iter()
        .find(|d| {
            d.is_intra_iteration()
                && d.from != d.to
                && group_of[d.from] != group_of[d.to]
                && !deps
                    .iter()
                    .any(|o| o.from == d.from && !o.is_intra_iteration())
        })
        .expect("gemm has a pure intra-iteration producer");

    let mut bad = schedule_symbolic(pra, &arch);
    bad.candidates.truncate(1);
    let lat = pra.eqs[dep.from].op.latency();
    let p = &mut bad.candidates[0];
    p.tau[dep.from] = p.tau[dep.to] + 1; // producer now after its consumer
    p.iter_len = p.iter_len.max(p.tau[dep.from] + lat);

    let rep = verify_symbolic(pra, &bad);
    assert!(!rep.is_legal(), "poisoned candidate accepted:\n{}", rep.summary());
    assert!(
        rep.candidates[0]
            .violations
            .iter()
            .any(|v| v.edge.from == dep.from && v.edge.to == dep.to),
        "offending edge not named:\n{}",
        rep.summary()
    );

    // instantiate() only replays the d ≠ 0 half, so the poison compiles…
    let cfg = compile_with(pra, &arch, &bad).expect("realize re-checks only d != 0");
    let r = simulate(&cfg, &arch, &ins).expect("io");
    // …and the freshly rebound d = 0 sink is RD-bound: counter-silent.
    assert_eq!(r.timing_violations, 0, "expected counter-silent breakage");
    // The output oracle is what catches it at runtime.
    let golden: ArrayData = pra.execute(&ins);
    assert!(
        r.outputs
            .iter()
            .any(|(name, vals)| golden.get(name).is_some_and(|g| g != vals)),
        "stale RD read did not corrupt any output"
    );
}
