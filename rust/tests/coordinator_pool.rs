//! Integration: coordinator v2 — the worker pool over the shared
//! single-flight compile cache. M workers × K duplicate requests must
//! compile each distinct kernel exactly once, produce the same responses as
//! a single-threaded session, and drain cleanly when the sender drops.

use std::collections::HashSet;

use repro::coordinator::{pool, CompileCache, Request, Session, Target, WorkloadKey};

fn mixed_trace(n_req: usize) -> Vec<Request> {
    // the shared trace shape, over a smaller workload set to keep tests fast
    Request::round_robin(&["gemm", "atax", "gesummv"], 8, n_req, 7)
}

fn response_key(r: &repro::coordinator::Response) -> String {
    format!(
        "{} {} n={} {:?} lat={} batch={} validated={:?} err={:?}",
        r.id,
        r.workload,
        r.n,
        r.target,
        r.latency_cycles,
        r.batch_cycles,
        r.validated,
        r.error
    )
}

/// The content address a trace request resolves to (for the single-flight
/// invariant checks).
fn key_of(r: &Request) -> WorkloadKey {
    let spec = repro::bench::spec::WorkloadCatalog::builtin()
        .spec(r.workload.name(), r.workload.n())
        .expect("trace uses builtin names");
    WorkloadKey::of(&spec, r.target)
}

#[test]
fn duplicate_requests_compile_each_kernel_exactly_once() {
    let trace = mixed_trace(24);
    let distinct: HashSet<WorkloadKey> = trace.iter().map(key_of).collect();

    let (tx, rx, handle) = pool::serve(4);
    let cache = handle.cache().clone();
    for r in &trace {
        tx.send(r.clone()).unwrap();
    }
    for _ in 0..trace.len() {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    drop(tx);
    let m = handle.join();

    assert_eq!(
        cache.stats.compiles(),
        distinct.len() as u64,
        "single-flight must compile each content address once"
    );
    assert_eq!(
        m.cache_hits + m.cache_misses,
        trace.len() as u64,
        "every request consults the cache"
    );
    assert_eq!(m.served, trace.len() as u64);
    assert_eq!(m.workers, 4);
}

#[test]
fn pool_responses_match_single_threaded_session() {
    let trace = mixed_trace(18);

    // sequential oracle
    let mut session = Session::new();
    let mut want: Vec<String> = trace.iter().map(|r| response_key(&session.handle(r))).collect();
    want.sort();

    // pooled run over the same trace
    let (tx, rx, handle) = pool::serve(4);
    for r in &trace {
        tx.send(r.clone()).unwrap();
    }
    let mut got: Vec<String> = (0..trace.len())
        .map(|_| response_key(&rx.recv().unwrap()))
        .collect();
    got.sort();
    drop(tx);
    handle.join();

    assert_eq!(got, want, "pool must be observationally equal to a session");
}

#[test]
fn dropping_the_sender_drains_in_flight_work() {
    let trace = mixed_trace(12);
    let (tx, rx, handle) = pool::serve(3);
    for r in &trace {
        tx.send(r.clone()).unwrap();
    }
    // hang up immediately: everything already queued must still be served
    drop(tx);
    let mut served = 0;
    while let Ok(r) = rx.recv() {
        assert!(r.error.is_none(), "{:?}", r.error);
        served += 1;
    }
    assert_eq!(served, trace.len(), "queued requests lost on shutdown");
    let m = handle.join();
    assert_eq!(m.served, trace.len() as u64);
}

#[test]
fn repeat_requests_replay_from_the_exec_cache() {
    // the acceptance criterion of the execution-plane PR: a byte-identical
    // repeat of a `(workload, n, target, seed, batch)` request must hit the
    // exec cache — no plan lowering, no simulation, no input regeneration —
    // asserted via the pool's merged metrics counters
    let (tx, rx, handle) = pool::serve(4);
    let exec_stats_probe = handle.exec_cache().clone();
    let req = Request::named(0, "gemm", 8, Target::Tcpa, 2, false, 9);
    tx.send(req.clone()).unwrap();
    let first = rx.recv().unwrap();
    assert!(first.error.is_none(), "{:?}", first.error);
    assert!(!first.exec_cache_hit, "cold request must execute");

    let repeats: u64 = 6;
    for i in 1..=repeats {
        let mut r = req.clone();
        r.id = i; // a new id is still the *same* execution key
        tx.send(r).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.exec_cache_hit, "repeat {i} must replay");
        assert!(resp.cache_hit, "replay implies artifact reuse");
        assert_eq!(resp.latency_cycles, first.latency_cycles, "byte-identical");
        assert_eq!(resp.batch_cycles, first.batch_cycles);
    }
    drop(tx);
    let m = handle.join();
    assert_eq!(m.exec_misses, 1, "exactly one execution ran");
    assert_eq!(m.exec_hits, repeats, "every repeat replayed");
    assert_eq!(exec_stats_probe.stats.execs(), 1, "no re-simulation");
    assert_eq!(
        m.input_misses, 1,
        "inputs were generated exactly once process-wide"
    );
    assert_eq!(m.cache_misses, 1, "one compile; repeats never re-lower");
}

#[test]
fn prewarmed_cache_serves_hits_only() {
    let cache = std::sync::Arc::new(CompileCache::new());
    // warm synchronously through a session sharing the cache
    let mut warmer = Session::with_cache(cache.clone());
    let trace = mixed_trace(12);
    for r in &trace {
        warmer.handle(r);
    }
    let compiles_after_warm = cache.stats.compiles();

    let (tx, rx, handle) = pool::serve_with_cache(4, cache.clone());
    for r in &trace {
        tx.send(r.clone()).unwrap();
    }
    for _ in 0..trace.len() {
        assert!(rx.recv().unwrap().error.is_none());
    }
    drop(tx);
    let m = handle.join();
    assert_eq!(cache.stats.compiles(), compiles_after_warm, "no recompiles");
    assert_eq!(m.cache_misses, 0, "pre-warmed pool must only hit");
}
