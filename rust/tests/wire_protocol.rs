//! Integration: the open workload API end to end — catalog vs inline specs,
//! content-addressed cache dedup, and the JSONL wire protocol serving a
//! kernel that is *not* in the builtin set on both array targets.

use std::sync::Arc;

use repro::backend::{compile_stats, TcpaBackend};
use repro::bench::spec::{WorkloadBuilder, WorkloadCatalog, WorkloadSpec};
use repro::bench::workloads::{build, BenchId};
use repro::coordinator::{wire, Request, Session, Target, WorkloadKey};
use repro::ir::affine::AffineMap;
use repro::ir::loopnest::{idx, idx_plus, ArrayKind, Expr, NestBuilder};
use repro::ir::op::{Dtype, OpKind};
use repro::ir::pra::PraBuilder;
use repro::ir::space::CondSpace;
use repro::util::json::Json;

/// A 5-point Jacobi-style stencil over the (n−2)×(n−2) interior — the same
/// non-PolyBench kernel `examples/custom_workload.rs` serves.
fn jacobi2d_spec(n: i64) -> WorkloadSpec {
    let d = 2;
    let m = n - 2;
    let nest = NestBuilder::new("jacobi2d", Dtype::I32)
        .dim("i0", m)
        .dim("i1", m)
        .array("A", vec![n, n], ArrayKind::Input)
        .array("S", vec![n, n], ArrayKind::Output)
        .stmt(
            "S",
            vec![idx_plus(d, 0, 1), idx_plus(d, 1, 1)],
            Expr::bin(
                OpKind::Add,
                Expr::read(0, vec![idx_plus(d, 0, 1), idx_plus(d, 1, 1)]),
                Expr::bin(
                    OpKind::Add,
                    Expr::bin(
                        OpKind::Add,
                        Expr::read(0, vec![idx(d, 0), idx_plus(d, 1, 1)]),
                        Expr::read(0, vec![idx_plus(d, 0, 2), idx_plus(d, 1, 1)]),
                    ),
                    Expr::bin(
                        OpKind::Add,
                        Expr::read(0, vec![idx_plus(d, 0, 1), idx(d, 1)]),
                        Expr::read(0, vec![idx_plus(d, 0, 1), idx_plus(d, 1, 2)]),
                    ),
                ),
            ),
        )
        .finish();
    let ident_off = |r: i64, c: i64| AffineMap::new(vec![vec![1, 0], vec![0, 1]], vec![r, c]);
    let b = PraBuilder::new("jacobi2d", Dtype::I32, vec![m, m])
        .var("h")
        .var("v")
        .var("hv")
        .array("A", vec![n, n], ArrayKind::Input)
        .array("S", vec![n, n], ArrayKind::Output);
    let left = b.input("A", ident_off(1, 0));
    let right = b.input("A", ident_off(1, 2));
    let up = b.input("A", ident_off(0, 1));
    let down = b.input("A", ident_off(2, 1));
    let center = b.input("A", ident_off(1, 1));
    let (h0, v0, hv0) = (b.v0("h"), b.v0("v"), b.v0("hv"));
    let pra = b
        .eq("H", "h", OpKind::Add, vec![left, right], CondSpace::all())
        .eq("V", "v", OpKind::Add, vec![up, down], CondSpace::all())
        .eq("HV", "hv", OpKind::Add, vec![h0, v0], CondSpace::all())
        .out_eq(
            "Out",
            "S",
            ident_off(1, 1),
            OpKind::Add,
            vec![hv0, center],
            CondSpace::all(),
        )
        .finish();
    WorkloadBuilder::new("jacobi2d", n, Dtype::I32)
        .stage(nest, pra)
        .uniform_input("A", vec![n, n], 1, 10)
        .finish()
        .expect("jacobi2d spec")
}

#[test]
fn jacobi_views_agree_with_each_other() {
    let spec = jacobi2d_spec(10);
    let wl = spec.workload();
    let ins = spec.gen_inputs(3);
    let a = wl.reference_nest(&ins);
    let b = wl.reference_pra(&ins);
    assert_eq!(wl.output_names(), vec!["S".to_string()]);
    assert_eq!(a["S"], b["S"], "nest and PRA views must agree");
}

/// The acceptance criterion: a kernel not in the builtin set is served end
/// to end from JSONL requests through the wire protocol on both TCPA and
/// CGRA targets, validated against the golden model, with a cache hit on
/// its second submission.
#[test]
fn non_builtin_kernel_served_end_to_end_via_jsonl() {
    let spec = jacobi2d_spec(10);
    let mut input = String::new();
    let mut id = 0;
    for _round in 0..2 {
        for target in [Target::Tcpa, Target::Cgra] {
            let req = Request::inline(id, spec.clone(), target, 1, true, 42);
            input.push_str(&wire::request_to_json(&req).render());
            input.push('\n');
            id += 1;
        }
    }
    // one worker => deterministic order and strict Hit (not Waited) repeats
    let mut out = Vec::new();
    let metrics = wire::serve_jsonl(
        &mut input.as_bytes(),
        &mut out,
        1,
        Arc::new(WorkloadCatalog::builtin()),
    )
    .expect("serve_jsonl");
    let lines: Vec<String> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| l.to_string())
        .collect();
    assert_eq!(lines.len(), 4, "one response line per request");
    let responses: Vec<_> = lines
        .iter()
        .map(|l| wire::response_from_json(&Json::parse(l).unwrap()).expect("response record"))
        .collect();
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "single worker preserves order");
        assert_eq!(r.workload, "jacobi2d");
        assert_eq!(r.n, 10);
        assert!(r.error.is_none(), "{:?}: {:?}", r.target, r.error);
        assert_eq!(r.validated, Some(true), "{:?} golden validation", r.target);
        assert!(r.latency_cycles > 0);
    }
    assert_eq!(responses[0].target, Target::Tcpa);
    assert_eq!(responses[1].target, Target::Cgra);
    assert!(!responses[0].cache_hit && !responses[1].cache_hit, "cold compiles");
    assert!(
        responses[2].cache_hit && responses[3].cache_hit,
        "second submission of an identical spec must hit the cache"
    );
    assert_eq!(metrics.served, 4);
    assert_eq!(metrics.distinct_kernels.len(), 2, "one kernel on two targets");
}

#[test]
fn malformed_jsonl_lines_become_error_records_without_aborting() {
    let input = format!(
        "not json at all\n\n{}\n{{\"v\":1,\"workload\":{{\"name\":\"gemm\",\"n\":8}}}}\n",
        wire::request_to_json(&Request::named(5, "gemm", 8, Target::Seq, 1, false, 0)).render()
    );
    let mut out = Vec::new();
    wire::serve_jsonl(
        &mut input.as_bytes(),
        &mut out,
        1,
        Arc::new(WorkloadCatalog::builtin()),
    )
    .unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "2 error records + 1 response: {text}");
    // serving streams, so error records and responses may interleave —
    // classify each record instead of assuming an order
    let (mut error_lines, mut responses) = (Vec::new(), Vec::new());
    for l in &lines {
        let j = Json::parse(l).unwrap();
        match j.get("line").and_then(Json::as_i64) {
            Some(lineno) => {
                assert!(
                    j.get("error").unwrap().as_str().is_some(),
                    "error record must carry a message: {l}"
                );
                error_lines.push(lineno);
            }
            None => responses.push(wire::response_from_json(&j).unwrap()),
        }
    }
    error_lines.sort_unstable();
    assert_eq!(error_lines, vec![1, 4], "blank lines still count in numbering");
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].id, 5);
    assert!(responses[0].error.is_none());
}

#[test]
fn inline_spec_of_a_builtin_content_addresses_to_the_named_artifact() {
    // a builtin spec round-tripped through the wire encoding must produce
    // the same WorkloadKey — i.e. a user re-submitting gemm inline dedupes
    // onto the catalog's compiled artifact
    let named = WorkloadCatalog::builtin().spec("gemm", 8).unwrap();
    let wire_trip =
        WorkloadSpec::from_json(&Json::parse(&named.to_json().render()).unwrap()).unwrap();
    assert_eq!(
        WorkloadKey::of(&named, Target::Cgra),
        WorkloadKey::of(&wire_trip, Target::Cgra)
    );

    // and a live session observes the dedup as a cache hit
    let mut s = Session::new();
    let r1 = s.handle(&Request::named(1, "gemm", 8, Target::Cgra, 1, false, 3));
    let r2 = s.handle(&Request::inline(2, wire_trip, Target::Cgra, 1, false, 3));
    assert!(r1.error.is_none() && r2.error.is_none());
    assert!(!r1.cache_hit);
    assert!(r2.cache_hit, "inline resubmission must not recompile");
    assert_eq!(r1.latency_cycles, r2.latency_cycles);
    assert_eq!(s.cache().stats.compiles(), 1);
}

#[test]
fn catalog_entries_produce_byte_identical_table_rows() {
    // Table II rows are rendered from MappedStats; the catalog path and the
    // BenchId shim path must yield identical row cells for every builtin
    let cat = WorkloadCatalog::builtin();
    let backend = TcpaBackend::paper(4, 4);
    for id in BenchId::ALL {
        let via_shim = build(id, 8);
        let via_catalog = cat.spec(id.name(), 8).unwrap().workload();
        let a = compile_stats(&backend, &via_shim);
        let b = compile_stats(&backend, &via_catalog);
        let row = |s: &repro::backend::MappedStats| {
            format!(
                "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{:?}|{:?}",
                s.workload,
                s.n,
                s.tool_label(),
                s.opt,
                s.arch,
                s.n_loops,
                s.n_ops,
                s.ii,
                s.unused_pes,
                s.max_ops_per_pe,
                s.latency
            )
        };
        assert_eq!(row(&a), row(&b), "{} Table II row", id.name());
    }
}

#[test]
fn custom_catalog_serves_by_name_through_the_pool() {
    use repro::coordinator::{pool, CompileCache};
    let mut catalog = WorkloadCatalog::builtin();
    catalog.register("jacobi2d", jacobi2d_spec);
    let (tx, rx, handle) = pool::serve_with(2, Arc::new(CompileCache::new()), Arc::new(catalog));
    for (i, target) in [Target::Tcpa, Target::Cgra, Target::Seq].into_iter().enumerate() {
        tx.send(Request::named(i as u64, "jacobi2d", 10, target, 2, true, 7))
            .unwrap();
    }
    let mut got: Vec<_> = (0..3).map(|_| rx.recv().unwrap()).collect();
    got.sort_by_key(|r| r.id);
    for r in &got {
        assert!(r.error.is_none(), "{:?}: {:?}", r.target, r.error);
        assert_eq!(r.validated, Some(true));
        assert_eq!(r.workload, "jacobi2d");
    }
    drop(tx);
    let m = handle.join();
    assert_eq!(m.served, 3);
    assert_eq!(m.distinct_kernels.len(), 3);
}
