//! Simulator equivalence suite: the streaming, plan-driven simulators must
//! be observationally identical to the reference semantics for every
//! benchmark at ≥2 problem sizes — numerics against the reference
//! interpreters, timing against the schedule's closed forms and an
//! independent event-enumeration oracle (the same (tile, j, eq) scan the
//! pre-streaming simulator materialized as its sorted event vector), issue
//! counts exact, and zero timing violations/hazards.

use repro::bench::harness::map_cgra_row;
use repro::bench::toolchains::{rows_for, Tool};
use repro::bench::workloads::{build, inputs, BenchId};
use repro::cgra::sim as cgra_sim;
use repro::ir::loopnest::ArrayData;
use repro::ir::op::values_close;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::{compile, TcpaConfig};
use repro::tcpa::sim as tcpa_sim;

/// Independent timing oracle: enumerate every active equation instance and
/// fold the closed-form issue/commit times — no streams, no heap, no plan.
struct Expected {
    issued: u64,
    per_pe_done: Vec<u64>,
}

fn expected_timing(cfg: &TcpaConfig) -> Expected {
    let part = &cfg.part;
    let sched = &cfg.sched;
    let pra = &cfg.pra;
    let mut per_pe_done = vec![0u64; part.inter.size() as usize];
    let mut issued = 0u64;
    for (tr, k) in part.inter.points().enumerate() {
        let start = sched.pe_start(&k);
        for j in part.intra.points() {
            let i = part.global(&k, &j);
            let ibase = start + sched.iter_start(&j);
            for (e, eq) in pra.eqs.iter().enumerate() {
                if !eq.cond.contains(&i) {
                    continue;
                }
                issued += 1;
                let done = ibase + sched.tau[e] as i64 + eq.op.latency() as i64;
                per_pe_done[tr] = per_pe_done[tr].max(done.max(0) as u64);
            }
        }
    }
    Expected {
        issued,
        per_pe_done,
    }
}

fn check_tcpa(id: BenchId, n: i64) {
    let wl = build(id, n);
    let arch = TcpaArch::paper(4, 4);
    let cfgs: Vec<_> = wl
        .pras
        .iter()
        .map(|p| compile(p, &arch).unwrap_or_else(|e| panic!("{} N={n}: {e}", id.name())))
        .collect();
    let ins = inputs(id, n, 23);
    let want = wl.reference_pra(&ins);
    let run = tcpa_sim::simulate_workload(&cfgs, &arch, &ins).expect("simulate");
    assert_eq!(run.kernels.len(), cfgs.len());
    for (cfg, kr) in cfgs.iter().zip(&run.kernels) {
        assert_eq!(kr.timing_violations, 0, "{} N={n}: violations", id.name());
        let exp = expected_timing(cfg);
        assert_eq!(kr.issued_ops, exp.issued, "{} N={n}: issued ops", id.name());
        assert_eq!(
            kr.per_pe_done,
            exp.per_pe_done,
            "{} N={n}: per-PE completion times",
            id.name()
        );
        assert_eq!(
            kr.cycles,
            cfg.last_pe_latency(),
            "{} N={n}: last-PE closed form",
            id.name()
        );
        assert_eq!(
            kr.first_pe_done,
            exp.per_pe_done.iter().copied().min().unwrap_or(0),
            "{} N={n}: first-PE completion",
            id.name()
        );
        // the closed form upper-bounds the measurement; equality requires
        // the first tile's last iteration to fire its longest slot (true
        // for GEMM — asserted in tcpa::sim's unit tests — but not for the
        // triangular kernels whose above-diagonal tiles are fully inactive)
        assert!(
            kr.first_pe_done <= cfg.first_pe_latency(),
            "{} N={n}: first-PE bound",
            id.name()
        );
    }
    for name in wl.output_names() {
        for (idx, (a, b)) in want[&name].iter().zip(run.outputs[&name].iter()).enumerate() {
            assert!(
                values_close(id.dtype(), *a, *b),
                "{} N={n} {name}[{idx}]: {a} vs {b}",
                id.name()
            );
        }
    }
}

fn check_cgra(id: BenchId, n: i64) {
    let wl = build(id, n);
    let ins = inputs(id, n, 23);
    let want = wl.reference_nest(&ins);
    // the register-aware (Morpher-like) profile: hazards must be zero
    let spec = rows_for(wl.n_loops, 4, 4)
        .into_iter()
        .find(|s| s.tool == Tool::Morpher)
        .expect("morpher row");
    let row = map_cgra_row(&wl, &spec);
    assert!(row.error.is_none(), "{} N={n}: {:?}", id.name(), row.error);
    let mut pool = ins.clone();
    let mut got = ArrayData::new();
    for (dfg, m) in &row.mappings {
        let r = cgra_sim::simulate(dfg, m, &pool);
        assert_eq!(r.timing_hazards, 0, "{} N={n}: hazards", id.name());
        assert_eq!(
            r.cycles,
            m.latency(dfg.iters),
            "{} N={n}: CGRA latency closed form",
            id.name()
        );
        assert_eq!(
            r.issued_ops,
            dfg.n_nodes() as u64 * dfg.iters,
            "{} N={n}: CGRA issued ops",
            id.name()
        );
        for (k, v) in r.outputs {
            pool.insert(k.clone(), v.clone());
            got.insert(k, v);
        }
    }
    for name in wl.output_names() {
        for (idx, (a, b)) in want[&name].iter().zip(got[&name].iter()).enumerate() {
            assert!(
                values_close(id.dtype(), *a, *b),
                "{} N={n} {name}[{idx}]: {a} vs {b}",
                id.name()
            );
        }
    }
}

fn check_both(id: BenchId, sizes: &[i64]) {
    for &n in sizes {
        check_tcpa(id, n);
        check_cgra(id, n);
    }
}

/// Plan-hoisting invariant: one *shared* `Arc<ExecPlan>` set executed twice
/// must be bit-identical to two fresh `simulate` calls — cycles, outputs,
/// issued ops, per-PE completions — proving hoisted plans carry no mutable
/// state (the property the compile cache relies on when concurrent workers
/// replay one cached artifact).
#[test]
fn shared_exec_plans_replay_bit_identically() {
    use std::sync::Arc;
    for (id, n) in [(BenchId::Gemm, 8), (BenchId::Atax, 8), (BenchId::Trisolv, 8)] {
        let wl = build(id, n);
        let arch = TcpaArch::paper(4, 4);
        let cfgs: Vec<_> = wl
            .pras
            .iter()
            .map(|p| compile(p, &arch).expect("compile"))
            .collect();
        let plans: Vec<Arc<repro::tcpa::plan::ExecPlan>> = cfgs
            .iter()
            .map(|c| Arc::new(c.execution_plan()))
            .collect();
        let ins = inputs(id, n, 23);
        // two executions over the *same* shared plans...
        let h1 = tcpa_sim::simulate_workload_with_plans(&cfgs, &plans, &arch, &ins)
            .expect("hoisted 1");
        let h2 = tcpa_sim::simulate_workload_with_plans(&cfgs, &plans, &arch, &ins)
            .expect("hoisted 2");
        // ...and two fresh per-call lowerings
        let f1 = tcpa_sim::simulate_workload(&cfgs, &arch, &ins).expect("fresh 1");
        let f2 = tcpa_sim::simulate_workload(&cfgs, &arch, &ins).expect("fresh 2");
        for run in [&h2, &f1, &f2] {
            assert_eq!(h1.outputs, run.outputs, "{}: outputs", id.name());
            assert_eq!(h1.total_latency, run.total_latency, "{}: cycles", id.name());
            assert_eq!(
                h1.overlapped_latency,
                run.overlapped_latency,
                "{}: overlap",
                id.name()
            );
            assert_eq!(h1.kernels.len(), run.kernels.len());
            for (a, b) in h1.kernels.iter().zip(&run.kernels) {
                assert_eq!(a.issued_ops, b.issued_ops, "{}: issued", id.name());
                assert_eq!(a.per_pe_done, b.per_pe_done, "{}: per-PE", id.name());
                assert_eq!(a.cycles, b.cycles);
                assert_eq!(a.first_pe_done, b.first_pe_done);
                assert_eq!(a.timing_violations, 0);
            }
        }
    }
}

/// Symbolic-vs-concrete oracle: one per-shape symbolic compile (recorded at
/// the smallest size) instantiated at size `n` must be bit-identical to the
/// per-n concrete compile for every benchmark — `MappedStats`, cycles,
/// issued ops and outputs on success; stage, message and partial stats on
/// failure. Each size is instantiated *before* any concrete compile at that
/// size runs, so the oracle covers sizes the concrete pipeline has never
/// seen when the symbolic artifact answers.
#[test]
fn symbolic_instantiation_matches_concrete_compiles_for_all_benchmarks() {
    use repro::backend::{Backend, TcpaBackend};
    use repro::bench::workloads::builtin_spec;
    let be = TcpaBackend::paper(4, 4);
    let sizes = [8i64, 12, 16];
    for id in BenchId::ALL {
        let sym = be
            .compile_symbolic(&builtin_spec(id, sizes[0]))
            .unwrap_or_else(|| panic!("{}: must be shape-eligible", id.name()));
        for &n in &sizes {
            let inst = sym.instantiate(n);
            let fresh = be.compile(&build(id, n));
            match (inst, fresh) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.stats(), b.stats(), "{} N={n}: stats", id.name());
                    let ins = inputs(id, n, 23);
                    let ra = a.execute(&ins, 3).expect("instantiated exec");
                    let rb = b.execute(&ins, 3).expect("fresh exec");
                    assert_eq!(
                        ra.latency_cycles,
                        rb.latency_cycles,
                        "{} N={n}: cycles",
                        id.name()
                    );
                    assert_eq!(
                        ra.batch_cycles,
                        rb.batch_cycles,
                        "{} N={n}: batch cycles",
                        id.name()
                    );
                    assert_eq!(ra.issued_ops, rb.issued_ops, "{} N={n}: issued", id.name());
                    assert_eq!(ra.outputs, rb.outputs, "{} N={n}: outputs", id.name());
                }
                (Err(a), Err(b)) => {
                    assert_eq!(a.stage, b.stage, "{} N={n}: stage", id.name());
                    assert_eq!(a.message, b.message, "{} N={n}: message", id.name());
                    assert_eq!(a.stats, b.stats, "{} N={n}: partial stats", id.name());
                }
                (a, b) => panic!(
                    "{} N={n}: symbolic and concrete paths diverged: {:?} vs {:?}",
                    id.name(),
                    a.map(|m| m.stats().clone()),
                    b.map(|m| m.stats().clone())
                ),
            }
        }
    }
}

/// The error paths through the oracle: sizes the TCPA pipeline rejects must
/// be rejected identically by instantiation — same stage, same message,
/// same partial stats (the paper's tables print failed rows too).
#[test]
fn symbolic_instantiation_reproduces_failure_sizes_bit_identically() {
    use repro::backend::{Backend, TcpaBackend};
    use repro::bench::workloads::builtin_spec;
    let be = TcpaBackend::paper(4, 4);
    let sym = be
        .compile_symbolic(&builtin_spec(BenchId::Gemm, 8))
        .expect("gemm is shape-eligible");
    // n=10 does not divide the 4×4 grid; n=32 exceeds the FIFO budget
    for n in [10i64, 32] {
        let a = sym.instantiate(n).expect_err("gemm must fail at this size");
        let b = be
            .compile(&build(BenchId::Gemm, n))
            .expect_err("gemm must fail at this size");
        assert_eq!(a.stage, b.stage, "N={n}: stage");
        assert_eq!(a.message, b.message, "N={n}: message");
        assert_eq!(a.stats, b.stats, "N={n}: partial stats");
    }
}

#[test]
fn gemm_equivalence_two_sizes() {
    // 12 stays under the §IV-6 FIFO budget on the 4×4 array
    check_both(BenchId::Gemm, &[8, 12]);
}

#[test]
fn atax_equivalence_two_sizes() {
    check_both(BenchId::Atax, &[8, 16]);
}

#[test]
fn gesummv_equivalence_two_sizes() {
    check_both(BenchId::Gesummv, &[8, 16]);
}

#[test]
fn mvt_equivalence_two_sizes() {
    check_both(BenchId::Mvt, &[8, 16]);
}

#[test]
fn trisolv_equivalence_two_sizes() {
    check_both(BenchId::Trisolv, &[8, 16]);
}

#[test]
fn trsm_equivalence_two_sizes() {
    check_both(BenchId::Trsm, &[8, 16]);
}
