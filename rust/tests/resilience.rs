//! Chaos suite for the resilience plane (compiled only with
//! `--features fault-injection`; CI runs it as a dedicated tier-1 step).
//!
//! Every test drives the *public* pool API and checks the same contract
//! from both sides of the wire: each request yields exactly one typed
//! response, and the merged [`repro::coordinator::Metrics`] counters
//! reconcile exactly with what the responses themselves say — shed,
//! timeouts, degraded, retries — under overload, panic storms, deadline
//! pressure and combined fault schedules. Fault decisions come from a
//! seeded [`FaultPlan`]: a pure hash of `(seed, site, request id)`, so a
//! failing run reproduces from its seed regardless of worker interleaving.

#![cfg(feature = "fault-injection")]

use std::sync::Arc;
use std::time::Duration;

use repro::bench::spec::WorkloadCatalog;
use repro::coordinator::pool::{run_trace_configured, serve_configured, PoolConfig};
use repro::coordinator::{
    CompileCache, ErrorKind, ExecCache, FaultPlan, FaultSite, Request, Response, Target,
};

/// The serve bench's trace shape: all six catalog kernels round-robined
/// across both array targets with cycling batch sizes.
fn mixed_trace(n_req: usize) -> Vec<Request> {
    let catalog = WorkloadCatalog::builtin();
    let names = catalog.names();
    let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    Request::round_robin(&names, 8, n_req, 0)
}

/// Every id in `0..n` is answered exactly once (no drops, no duplicates).
fn assert_exactly_one_response_each(responses: &[Response], n: usize) {
    assert_eq!(responses.len(), n, "one response per request");
    let mut seen = vec![false; n];
    for r in responses {
        let slot = &mut seen[r.id as usize];
        assert!(!*slot, "request {} answered twice", r.id);
        *slot = true;
    }
}

#[test]
fn overload_shedding_keeps_the_response_identity() {
    // an open-loop 48-request burst into a 2-slot queue: the overflow must
    // be shed with typed responses, and shed + failed + served must cover
    // the whole burst — nothing dropped, nothing double-counted
    let config = PoolConfig {
        queue_cap: Some(2),
        ..PoolConfig::default()
    };
    let n_req = 48;
    let trace = mixed_trace(n_req);
    let (_, m, responses) = run_trace_configured(2, &trace, config);
    assert_exactly_one_response_each(&responses, n_req);
    let mut shed_responses = 0u64;
    for r in &responses {
        if r.error_kind == Some(ErrorKind::Shed) {
            shed_responses += 1;
            assert!(
                r.error.as_deref().unwrap_or("").contains("shed"),
                "shed responses carry the shed message: {:?}",
                r.error
            );
            assert!(!r.degraded, "a shed request never reaches a backend");
        }
    }
    assert!(m.shed > 0, "a {n_req}-deep burst over a 2-slot queue must shed");
    assert_eq!(m.shed, shed_responses, "metrics.shed matches the Shed responses on the wire");
    assert_eq!(
        m.shed + m.failed + m.served,
        n_req as u64,
        "admission identity: every request is shed, failed, or served"
    );
}

#[test]
fn panic_storm_poisons_once_and_recovers() {
    // 60 distinct-seed requests under injected compile/exec panics: every
    // request still gets a typed response, panicked flights are poisoned
    // (visible in metrics), and a fault-free pool over the *same* caches
    // afterwards serves the identical trace with 100% success — poisoned
    // entries never wedge the cache.
    let plan = Arc::new(
        FaultPlan::new(7)
            .with_rate(FaultSite::CompilePanic, 400)
            .with_rate(FaultSite::ExecPanic, 200),
    );
    let cache = Arc::new(CompileCache::new());
    let exec_cache = Arc::new(ExecCache::new());
    let catalog = Arc::new(WorkloadCatalog::builtin());
    let n_req = 60;
    let trace: Vec<Request> = (0..n_req)
        .map(|i| {
            let target = if i % 2 == 0 { Target::Tcpa } else { Target::Cgra };
            Request::named(i as u64, "gemm", 8, target, 1, false, i as u64)
        })
        .collect();

    let config = PoolConfig {
        faults: Some(plan.clone()),
        ..PoolConfig::default()
    };
    let (tx, rx, handle) =
        serve_configured(3, cache.clone(), exec_cache.clone(), catalog.clone(), config);
    for r in &trace {
        tx.send(r.clone()).expect("pool alive");
    }
    let responses: Vec<Response> = (0..n_req).map(|_| rx.recv().expect("pool response")).collect();
    drop(tx);
    let m = handle.join();

    assert_exactly_one_response_each(&responses, n_req);
    let fired = plan.injected(FaultSite::CompilePanic) + plan.injected(FaultSite::ExecPanic);
    assert!(fired > 0, "the storm must actually inject panics (seed 7)");
    assert!(
        m.poisoned_flights > 0,
        "a panicked single-flight leader poisons its entry exactly once"
    );
    assert_eq!(m.worker_panics, 0, "injected panics are quarantined inside the flight");
    for r in &responses {
        if let Some(e) = &r.error {
            assert_eq!(r.error_kind, Some(ErrorKind::Failed), "{e}");
            assert!(e.contains("[panic]"), "storm failures are panic-typed: {e}");
        }
    }
    let wire_retries: u64 = responses.iter().map(|r| r.retries).sum();
    assert_eq!(m.retries, wire_retries, "metrics.retries matches the per-response retry counts");
    assert_eq!(m.shed + m.failed + m.served, n_req as u64);

    // recovery: same caches, no faults, identical trace — all 60 succeed
    let (tx, rx, handle) = serve_configured(3, cache, exec_cache, catalog, PoolConfig::default());
    for r in &trace {
        tx.send(r.clone()).expect("pool alive");
    }
    let responses: Vec<Response> = (0..n_req).map(|_| rx.recv().expect("pool response")).collect();
    drop(tx);
    let m2 = handle.join();
    assert_exactly_one_response_each(&responses, n_req);
    for r in &responses {
        assert!(r.error.is_none(), "post-storm replay must fully recover: {:?}", r.error);
    }
    assert_eq!(m2.served, n_req as u64);
    assert_eq!(m2.failed, 0);
}

#[test]
fn deadline_sweep_times_out_cleanly() {
    // zero budget: expires at admission, before burning a queue slot
    let n_req = 4;
    let trace: Vec<Request> = (0..n_req)
        .map(|i| {
            Request::named(i as u64, "gemm", 8, Target::Tcpa, 1, false, i as u64)
                .with_deadline_ms(0)
        })
        .collect();
    let (_, m, responses) = run_trace_configured(2, &trace, PoolConfig::default());
    assert_exactly_one_response_each(&responses, n_req);
    for r in &responses {
        assert_eq!(r.error_kind, Some(ErrorKind::Timeout));
        let e = r.error.as_deref().unwrap_or("");
        assert!(e.contains("[deadline]") && e.contains("admission"), "{e}");
    }
    assert_eq!(m.timeouts, n_req as u64);
    assert_eq!(m.failed, n_req as u64, "timeouts are a subset of failed");

    // tight budget + injected 50ms compile stall: the deadline fires at a
    // pipeline stage boundary, not at admission
    let plan = Arc::new(
        FaultPlan::new(11)
            .with_rate(FaultSite::CompileDelay, 1000)
            .with_delay(Duration::from_millis(50)),
    );
    let config = PoolConfig {
        faults: Some(plan.clone()),
        ..PoolConfig::default()
    };
    let trace = vec![Request::named(0, "atax", 8, Target::Tcpa, 1, false, 9).with_deadline_ms(10)];
    let (_, m, responses) = run_trace_configured(1, &trace, config);
    assert_eq!(responses.len(), 1);
    let r = &responses[0];
    assert_eq!(r.error_kind, Some(ErrorKind::Timeout), "{:?}", r.error);
    let e = r.error.as_deref().unwrap_or("");
    assert!(e.contains("[deadline]"), "{e}");
    assert!(!e.contains("admission"), "the stall expires the budget *after* admission: {e}");
    assert_eq!(plan.injected(FaultSite::CompileDelay), 1);
    assert_eq!(m.timeouts, 1);

    // generous budget: every catalog kernel on both targets beats 10s
    let n_req = 12;
    let trace: Vec<Request> = mixed_trace(n_req)
        .into_iter()
        .map(|r| r.with_deadline_ms(10_000))
        .collect();
    let (_, m, responses) = run_trace_configured(2, &trace, PoolConfig::default());
    assert_exactly_one_response_each(&responses, n_req);
    for r in &responses {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    assert_eq!(m.served, n_req as u64);
    assert_eq!(m.timeouts, 0);
}

#[test]
fn degraded_fallback_serves_unmappable_kernels() {
    // two unmappable array requests opted into fallback (a CGRA kernel past
    // the fabric and a TCPA size that doesn't tile), one byte-identical
    // repeat, and one non-opted-in control
    let trace = vec![
        Request::named(0, "gemm", 64, Target::Cgra, 1, false, 1).with_fallback(),
        Request::named(1, "gemm", 64, Target::Cgra, 1, false, 1).with_fallback(),
        Request::named(2, "gemm", 10, Target::Tcpa, 1, false, 1).with_fallback(),
        Request::named(3, "gemm", 64, Target::Cgra, 1, false, 1),
    ];
    let (_, m, mut responses) = run_trace_configured(2, &trace, PoolConfig::default());
    assert_exactly_one_response_each(&responses, trace.len());
    responses.sort_by_key(|r| r.id);
    for r in &responses[0..3] {
        assert!(r.error.is_none(), "fallback absorbs the compile failure: {:?}", r.error);
        assert!(r.degraded, "request {} must be marked degraded on the wire", r.id);
        assert_eq!(r.error_kind, None);
    }
    assert_eq!(responses[0].target, Target::Cgra, "the response echoes the *requested* target");
    assert_eq!(responses[2].target, Target::Tcpa);
    let ctrl = &responses[3];
    assert!(ctrl.error.is_some(), "without the opt-in the compile failure surfaces");
    assert_eq!(ctrl.error_kind, Some(ErrorKind::Failed));
    assert!(!ctrl.degraded);
    assert_eq!(m.degraded, 3);
    assert_eq!(m.served, 3, "degraded responses count as served");
    assert_eq!(m.failed, 1);
    let wire_degraded = responses.iter().filter(|r| r.degraded).count() as u64;
    assert_eq!(m.degraded, wire_degraded);
}

#[test]
fn chaos_identity_holds_under_combined_faults() {
    // everything at once: a 3-slot queue, compile/exec panic storms, queue
    // stalls, a sprinkle of zero-budget deadlines and unmappable fallback
    // requests. The invariant under the full storm is exact bookkeeping:
    // metrics and wire responses must agree counter for counter.
    let plan = Arc::new(
        FaultPlan::new(42)
            .with_rate(FaultSite::CompilePanic, 150)
            .with_rate(FaultSite::ExecPanic, 100)
            .with_rate(FaultSite::QueueStall, 100)
            .with_delay(Duration::from_millis(5)),
    );
    let config = PoolConfig {
        queue_cap: Some(3),
        faults: Some(plan.clone()),
        ..PoolConfig::default()
    };
    let n_req = 80;
    let trace: Vec<Request> = mixed_trace(n_req)
        .into_iter()
        .enumerate()
        .map(|(i, r)| match i % 16 {
            5 => r.with_deadline_ms(0),
            9 => Request::named(i as u64, "gemm", 10, Target::Tcpa, 1, false, 7).with_fallback(),
            _ => r,
        })
        .collect();
    let (_, m, responses) = run_trace_configured(3, &trace, config);
    assert_exactly_one_response_each(&responses, n_req);

    let shed_r = responses.iter().filter(|r| r.error_kind == Some(ErrorKind::Shed)).count() as u64;
    let timeout_r =
        responses.iter().filter(|r| r.error_kind == Some(ErrorKind::Timeout)).count() as u64;
    let degraded_r = responses.iter().filter(|r| r.degraded).count() as u64;
    let ok_r = responses.iter().filter(|r| r.error.is_none()).count() as u64;
    let err_r = responses.iter().filter(|r| r.error.is_some()).count() as u64;
    let retries_r: u64 = responses.iter().map(|r| r.retries).sum();

    assert_eq!(m.shed, shed_r, "shed");
    assert_eq!(m.timeouts, timeout_r, "timeouts");
    assert_eq!(m.degraded, degraded_r, "degraded");
    assert_eq!(m.retries, retries_r, "retries");
    assert_eq!(m.served, ok_r, "served == error-free responses");
    assert_eq!(m.failed + m.shed, err_r, "errored responses are exactly the failed + shed ones");
    assert_eq!(m.shed + m.failed + m.served, n_req as u64, "admission identity");
    assert_eq!(m.worker_panics, 0, "every injected panic is quarantined");
    // degraded responses are error-free and therefore inside served
    assert!(m.degraded <= m.served);
    // timeouts are failures, never successes
    assert!(m.timeouts <= m.failed);
}
