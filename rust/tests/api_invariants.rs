//! Source-level API invariants, enforced grep-style over `src/`:
//!
//! * No `match` arm on `BenchId` outside `bench/workloads.rs` — the
//!   benchmark set is open (catalog + specs); the shim's own registration
//!   is the single allowed site. Mirrors PR 3's backend invariant:
//! * No `match` arm on `Target` outside `src/backend/` — targets are
//!   dispatched through the registry, never by enum case analysis.
//!
//! The scan looks for `Enum::Variant =>` — the shape every match arm (and
//! nothing else in this codebase) takes.

use std::path::{Path, PathBuf};

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("read src dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rs_files(&path, out);
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
}

/// Does `src` contain `needle` followed (after an identifier and optional
/// whitespace) by `=>` — i.e. a match arm on that enum?
fn match_arms(src: &str, needle: &str) -> Vec<String> {
    let mut found = Vec::new();
    let bytes = src.as_bytes();
    let mut from = 0;
    while let Some(pos) = src[from..].find(needle) {
        let start = from + pos;
        let mut i = start + needle.len();
        let ident_start = i;
        while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
            i += 1;
        }
        let ident_end = i;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if ident_end > ident_start && bytes[i..].starts_with(b"=>") {
            let line = src[..start].matches('\n').count() + 1;
            found.push(format!(
                "line {line}: {}{}",
                needle,
                &src[ident_start..ident_end]
            ));
        }
        from = start + needle.len();
    }
    found
}

fn scan(needle: &str, allowed: &dyn Fn(&Path) -> bool) -> Vec<String> {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    rs_files(&src, &mut files);
    assert!(files.len() > 30, "scanner must see the whole tree");
    let mut violations = Vec::new();
    for f in files {
        if allowed(&f) {
            continue;
        }
        let text = std::fs::read_to_string(&f).expect("read source file");
        for hit in match_arms(&text, needle) {
            violations.push(format!("{}: {hit}", f.display()));
        }
    }
    violations
}

#[test]
fn no_match_on_benchid_outside_workloads_registration() {
    let violations = scan("BenchId::", &|p: &Path| {
        p.ends_with("bench/workloads.rs")
    });
    assert!(
        violations.is_empty(),
        "BenchId must not be matched on outside bench/workloads.rs \
         (use the catalog / Workload.name instead):\n{}",
        violations.join("\n")
    );
}

#[test]
fn no_match_on_target_outside_backend() {
    let violations = scan("Target::", &|p: &Path| {
        p.components().any(|c| c.as_os_str() == "backend")
    });
    assert!(
        violations.is_empty(),
        "Target must not be matched on outside src/backend/ \
         (dispatch through the BackendRegistry instead):\n{}",
        violations.join("\n")
    );
}

#[test]
fn scanner_detects_arms() {
    // the scanner itself must be able to see a match arm, or the
    // invariants above would vacuously pass
    let sample = "match id {\n    BenchId::Gemm => 1,\n    _ => 2,\n}";
    assert_eq!(match_arms(sample, "BenchId::").len(), 1);
    assert!(match_arms("let x = BenchId::Gemm;", "BenchId::").is_empty());
    assert!(match_arms("if id == BenchId::Gemm { }", "BenchId::").is_empty());
}
