//! Source-level API invariants — now a thin shim over the promoted
//! `repro lint` pass ([`repro::analysis::lint`]), which enforces:
//!
//! * No `match` arm on `BenchId` outside `bench/workloads.rs` — the
//!   benchmark set is open (catalog + specs); the shim's own registration
//!   is the single allowed site.
//! * No `match` arm on `Target` outside `src/backend/` — targets are
//!   dispatched through the registry, never by enum case analysis.
//! * No `.unwrap()` / `.expect(` on the serve hot path
//!   (`coordinator/{pool,net,wire,session}.rs`, non-test regions).
//! * No clock reads or allocation inside the simulators' marked inner
//!   loops (`// lint: begin-hot-loop` … `// lint: end-hot-loop`).
//!
//! The same pass runs standalone as `repro lint` (and in CI); this test
//! keeps it wired into plain `cargo test`.

use repro::analysis::lint;
use std::path::Path;

#[test]
fn source_tree_passes_repro_lint() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let issues = lint::run(&src).expect("lint scan");
    assert!(
        issues.is_empty(),
        "`repro lint` found {} issue(s):\n{}",
        issues.len(),
        issues
            .iter()
            .map(|i| i.describe())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn scanner_detects_arms() {
    // the arm scanner must be able to see a match arm, or the invariants
    // above would vacuously pass
    let sample = "match id {\n    BenchId::Gemm => 1,\n    _ => 2,\n}";
    assert_eq!(lint::match_arms(sample, "BenchId::").len(), 1);
    assert!(lint::match_arms("let x = BenchId::Gemm;", "BenchId::").is_empty());
    assert!(lint::match_arms("if id == BenchId::Gemm { }", "BenchId::").is_empty());
}
