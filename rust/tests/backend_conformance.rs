//! Backend conformance suite: every backend in the default registry must
//! honor the unified compile→execute→report contract, parameterized over
//! all six benchmarks.
//!
//! Contract points checked here:
//! * outputs match the golden interpreter on every benchmark;
//! * `batch = 1` costs exactly the single-invocation latency;
//! * batch latency is monotone (non-decreasing) in batch size and never
//!   beats the per-target lower bound of one full invocation;
//! * an artifact with no pipelined latency (a CGRA inner-only row)
//!   surfaces as `Err` from `execute`, never as a zero-cycle success;
//! * the sequential reference backend is servable end to end through the
//!   coordinator pool, like any other target.

use repro::backend::{BackendRegistry, CgraBackend, Target};
use repro::bench::spec::WorkloadCatalog;
use repro::bench::toolchains::{rows_for, Tool};
use repro::bench::workloads::{build, inputs, BenchId};
use repro::coordinator::pool;
use repro::coordinator::Request;
use repro::ir::op::values_close;
use repro::runtime::golden::GoldenService;

const N: i64 = 8;

const SEED: u64 = 33;

#[test]
fn outputs_match_golden_on_every_backend_and_benchmark() {
    let registry = BackendRegistry::with_defaults();
    let mut golden = GoldenService::new();
    let cat = WorkloadCatalog::builtin();
    assert_eq!(registry.targets(), Target::ALL.to_vec(), "all targets registered");
    for target in registry.targets() {
        let backend = registry.get(target).unwrap();
        for id in BenchId::ALL {
            let wl = build(id, N);
            let ins = inputs(id, N, SEED);
            let mapped = backend
                .compile(&wl)
                .unwrap_or_else(|e| panic!("{} {}: compile failed: {e}", target.name(), id.name()));
            let rep = mapped
                .execute(&ins, 1)
                .unwrap_or_else(|e| panic!("{} {}: execute failed: {e}", target.name(), id.name()));
            assert!(rep.latency_cycles > 0, "{} {}", target.name(), id.name());
            assert_eq!(
                rep.batch_cycles,
                rep.latency_cycles,
                "{} {}: batch=1 must equal single latency",
                target.name(),
                id.name()
            );
            // occupancy is ops per PE-cycle; it can exceed 1 on the TCPA's
            // multi-FU PEs, but a successful run always issues work
            assert!(
                rep.occupancy > 0.0,
                "{} {}: occupancy {} must be positive",
                target.name(),
                id.name(),
                rep.occupancy
            );
            let (want, _) = golden
                .run(&cat.spec(id.name(), N).unwrap(), &ins)
                .expect("golden run");
            for name in wl.output_names() {
                let (a, b) = (&want[&name], &rep.outputs[&name]);
                assert_eq!(a.len(), b.len(), "{} {name}", target.name());
                for (x, y) in a.iter().zip(b.iter()) {
                    assert!(
                        values_close(id.dtype(), *x, *y),
                        "{} {} {name}: {x} vs {y}",
                        target.name(),
                        id.name()
                    );
                }
            }
        }
    }
}

#[test]
fn batch_latency_is_monotone_in_batch_size() {
    let registry = BackendRegistry::with_defaults();
    for target in registry.targets() {
        let backend = registry.get(target).unwrap();
        for id in [BenchId::Gemm, BenchId::Atax] {
            let wl = build(id, N);
            let ins = inputs(id, N, SEED);
            let mapped = backend.compile(&wl).expect("compiles");
            let mut prev = 0u64;
            for batch in [1u64, 2, 4, 8] {
                let rep = mapped.execute(&ins, batch).expect("executes");
                assert!(
                    rep.batch_cycles >= prev,
                    "{} {}: batch={batch} gave {} after {prev}",
                    target.name(),
                    id.name(),
                    rep.batch_cycles
                );
                assert!(
                    rep.batch_cycles >= rep.latency_cycles,
                    "{} {}: a batch can never undercut one invocation",
                    target.name(),
                    id.name()
                );
                prev = rep.batch_cycles;
            }
        }
    }
}

#[test]
fn cgra_missing_latency_surfaces_as_error_not_zero() {
    // inner-only rows map successfully but report no pipelined latency
    // over the full problem — executing one must be an Err. Flag the
    // known-good Morpher row inner-only so the mapping itself is the one
    // the rest of the suite already proves.
    let wl = build(BenchId::Gemm, N);
    let mut spec = rows_for(wl.n_loops, 4, 4)
        .into_iter()
        .find(|s| s.tool == Tool::Morpher)
        .expect("the Morpher Table II row");
    spec.inner_only = true;
    let mapped = CgraBackend::from_spec(spec)
        .compile(&wl)
        .expect("inner-only mapping compiles");
    assert!(mapped.stats().latency.is_none());
    let err = mapped
        .execute(&inputs(BenchId::Gemm, N, SEED), 1)
        .expect_err("no pipelined latency must not execute");
    assert!(err.contains("no pipelined latency"), "{err}");
}

#[test]
fn seq_backend_serves_end_to_end_through_the_pool() {
    let (tx, rx, handle) = pool::serve(2);
    let n_req = 6u64;
    for i in 0..n_req {
        let name = BenchId::ALL[i as usize % BenchId::ALL.len()].name();
        tx.send(Request::named(i, name, N, Target::Seq, 1 + i % 3, true, SEED + i))
            .unwrap();
    }
    for _ in 0..n_req {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.validated, Some(true), "{} seq validation", r.workload);
        assert!(r.latency_cycles > 0);
    }
    drop(tx);
    let m = handle.join();
    assert_eq!(m.served, n_req);
    assert_eq!(m.target(Target::Seq).served, n_req);
    assert_eq!(m.target(Target::Cgra).served, 0);
}
