//! Integration: the full iteration-centric path — PRA → LSGP partition →
//! schedule → register binding → codegen → cycle-accurate array simulation —
//! for every benchmark on multiple array sizes, plus the PAULA text
//! frontend feeding the same pipeline.

use repro::bench::workloads::{build, inputs, BenchId};
use repro::ir::loopnest::ArrayData;
use repro::ir::op::{values_close, Value};
use repro::ir::paula;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::config::compile;
use repro::tcpa::sim::{simulate, simulate_workload};

fn check(id: BenchId, n: i64, w: usize, h: usize) {
    let wl = build(id, n);
    let arch = TcpaArch::paper(w, h);
    let cfgs: Vec<_> = wl
        .pras
        .iter()
        .map(|p| compile(p, &arch).unwrap_or_else(|e| panic!("{}: {e}", id.name())))
        .collect();
    let ins = inputs(id, n, 13);
    let want = wl.reference_pra(&ins);
    let run = simulate_workload(&cfgs, &arch, &ins).expect("simulate");
    for k in &run.kernels {
        assert_eq!(k.timing_violations, 0, "{}", id.name());
    }
    for name in wl.output_names() {
        for (a, b) in want[&name].iter().zip(run.outputs[&name].iter()) {
            assert!(
                values_close(id.dtype(), *a, *b),
                "{}/{}: {a} vs {b}",
                id.name(),
                name
            );
        }
    }
}

#[test]
fn all_benchmarks_on_4x4() {
    for id in BenchId::ALL {
        check(id, 8, 4, 4);
    }
}

#[test]
fn all_benchmarks_on_2x2() {
    for id in BenchId::ALL {
        check(id, 8, 2, 2);
    }
}

#[test]
fn rectangular_benchmarks_on_2x4() {
    // non-square arrays exercise the x/y dim split
    for id in [BenchId::Gemm, BenchId::Gesummv, BenchId::Trisolv] {
        check(id, 8, 2, 4);
    }
}

#[test]
fn paper_sizes_simulate() {
    check(BenchId::Gemm, 20, 4, 4);
    check(BenchId::Gesummv, 16, 4, 4);
}

#[test]
fn paula_text_frontend_full_pipeline() {
    // Listing 1's GEMM written in PAULA, compiled and simulated
    let n = 4;
    let src = format!(
        r#"
program gemm_paula
dtype i32
space {n} {n} {n}
var a
var b
var p
var c
input  A {n} {n}
input  B {n} {n}
output C {n} {n}
eq S1a: a[i] = A[i0, i2]            if i1 == 0
eq S1b: a[i] = a[i0, i1-1, i2]      if i1 >= 1
eq S2a: b[i] = B[i2, i1]            if i0 == 0
eq S2b: b[i] = b[i0-1, i1, i2]      if i0 >= 1
eq S3:  p[i] = a[i] * b[i]
eq S4a: c[i] = p[i]                 if i2 == 0
eq S4b: c[i] = c[i0, i1, i2-1] + p[i] if i2 >= 1
eq S5C: C[i0, i1] = c[i]            if i2 == {last}
"#,
        n = n,
        last = n - 1
    );
    let pra = paula::parse(&src).expect("parse");
    let arch = TcpaArch::paper(2, 2);
    let cfg = compile(&pra, &arch).expect("compile");
    // pure C = A·B needs 4 copy-class slots (a, b, c-init, C-out) on 3 copy
    // units → II = 2 (the in-repo GEMM PRA folds the output into an Add and
    // reaches II = 1)
    assert!(cfg.sched.ii <= 2, "II = {}", cfg.sched.ii);

    let mut ins = ArrayData::new();
    let nn = (n * n) as usize;
    ins.insert(
        "A".into(),
        (0..nn).map(|i| Value::I32(i as i32 + 1)).collect(),
    );
    ins.insert(
        "B".into(),
        (0..nn).map(|i| Value::I32(i as i32 % 5 + 1)).collect(),
    );
    let run = simulate(&cfg, &arch, &ins).expect("simulate");
    assert_eq!(run.timing_violations, 0);
    // compare against a naive matmul
    let a = &ins["A"];
    let b = &ins["B"];
    for i in 0..n as usize {
        for j in 0..n as usize {
            let mut acc = 0i64;
            for k in 0..n as usize {
                acc += a[i * n as usize + k].as_i64() * b[k * n as usize + j].as_i64();
            }
            assert_eq!(
                run.outputs["C"][i * n as usize + j],
                Value::I32(acc as i32)
            );
        }
    }
}

#[test]
fn larger_array_reduces_first_pe_latency() {
    // §VI: more PEs → smaller tiles → earlier restart
    let wl = build(BenchId::Gesummv, 32);
    let small = compile(&wl.pras[0], &TcpaArch::paper(4, 4)).unwrap();
    let large = compile(&wl.pras[0], &TcpaArch::paper(8, 8)).unwrap();
    assert!(large.first_pe_latency() < small.first_pe_latency());
}

#[test]
fn wavefront_widens_gap_for_2d_kernels() {
    // §V-A: 2-D nests on a 2-D array — first PE finishes much earlier.
    // N = 16 so TRSM's 3-D tiles fit the 280-word FIFO budget (§IV-6; at
    // N = 32 its xb-propagation FIFO alone would need p1·p2 = 256 words).
    let wl = build(BenchId::Trisolv, 16);
    let cfg = compile(&wl.pras[0], &TcpaArch::paper(4, 4)).unwrap();
    let gap = cfg.last_pe_latency() - cfg.first_pe_latency();
    assert!(gap as f64 > 0.5 * cfg.first_pe_latency() as f64);
    // TRSM (3-D) utilizes PEs better: relatively smaller gap
    let wl3 = build(BenchId::Trsm, 16);
    let cfg3 = compile(&wl3.pras[0], &TcpaArch::paper(4, 4)).unwrap();
    let rel3 = (cfg3.last_pe_latency() - cfg3.first_pe_latency()) as f64
        / cfg3.last_pe_latency() as f64;
    let rel2 = gap as f64 / cfg.last_pe_latency() as f64;
    assert!(rel3 < rel2, "TRSM gap {rel3:.2} should be < TRISOLV gap {rel2:.2}");
}
