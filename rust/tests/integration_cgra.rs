//! Integration: the full operation-centric path — loop nest → (unroll) →
//! DFG → modulo-scheduled place & route → cycle-accurate simulation —
//! validated numerically against the reference interpreter for every
//! benchmark the 4×4 CGRA can hold.

use repro::bench::workloads::{build, inputs, BenchId};
use repro::cgra::arch::CgraArch;
use repro::cgra::config::CgraConfig;
use repro::cgra::mapper::{map, MapOpts};
use repro::cgra::sim::simulate;
use repro::frontend::dfg_gen::{generate, GenOpts};
use repro::frontend::transforms::unroll_innermost;
use repro::ir::loopnest::ArrayData;
use repro::ir::op::values_close;

fn run_and_check(id: BenchId, n: i64, gen_opts: &GenOpts, unroll: usize, arch: &CgraArch) {
    let wl = build(id, n);
    let ins = inputs(id, n, 21);
    let want = wl.reference_nest(&ins);
    let mut pool = ins.clone();
    let mut outs = ArrayData::new();
    for nest in &wl.stages {
        let nest_u = unroll_innermost(nest, unroll).expect("unroll");
        let gen = generate(&nest_u, gen_opts).expect("dfg");
        let m = map(&gen.dfg, arch, &gen.inter_iteration_hazards, &MapOpts::negotiated())
            .unwrap_or_else(|e| panic!("{} failed to map: {e}", id.name()));
        let r = simulate(&gen.dfg, &m, &pool);
        assert_eq!(
            r.timing_hazards,
            0,
            "{}: register-aware mapping must be hazard-free",
            id.name()
        );
        for (k, v) in r.outputs {
            pool.insert(k.clone(), v.clone());
            outs.insert(k, v);
        }
    }
    for name in wl.output_names() {
        for (a, b) in want[&name].iter().zip(outs[&name].iter()) {
            assert!(
                values_close(id.dtype(), *a, *b),
                "{}/{}: {a} vs {b}",
                id.name(),
                name
            );
        }
    }
}

#[test]
fn all_benchmarks_flat_classical() {
    for id in BenchId::ALL {
        run_and_check(id, 8, &GenOpts::flat(), 1, &CgraArch::classical(4, 4));
    }
}

#[test]
fn gemm_and_gesummv_naive_chain() {
    for id in [BenchId::Gemm, BenchId::Gesummv] {
        run_and_check(id, 8, &GenOpts::naive(), 1, &CgraArch::classical(4, 4));
    }
}

#[test]
fn unrolled_by_2_preserves_semantics() {
    for id in [BenchId::Gemm, BenchId::Gesummv, BenchId::Mvt] {
        run_and_check(id, 8, &GenOpts::flat(), 2, &CgraArch::classical(4, 4));
    }
}

#[test]
fn hycube_maps_and_validates() {
    run_and_check(BenchId::Gemm, 8, &GenOpts::flat(), 1, &CgraArch::hycube(4, 4));
    run_and_check(BenchId::Atax, 8, &GenOpts::flat(), 1, &CgraArch::hycube(4, 4));
}

#[test]
fn config_lowering_is_consistent_with_mapping() {
    let wl = build(BenchId::Gemm, 8);
    let gen = generate(&wl.stages[0], &GenOpts::flat()).unwrap();
    let arch = CgraArch::classical(4, 4);
    let m = map(&gen.dfg, &arch, &gen.inter_iteration_hazards, &MapOpts::negotiated()).unwrap();
    let cfg = CgraConfig::from_mapping(&gen.dfg, &arch, &m);
    assert_eq!(cfg.busy_slots(), gen.dfg.n_nodes());
    // utilization must be consistent with Table II's underutilization story
    assert!(cfg.fu_utilization() < 0.75);
}

#[test]
fn trisolv_divider_latency_respected() {
    // TRISOLV's divider (16 cycles) must not break timing
    run_and_check(BenchId::Trisolv, 8, &GenOpts::flat(), 1, &CgraArch::classical(4, 4));
}
