//! Integration: bounded server-side caches. The compile cache and the exec
//! cache are LRU-bounded and single-flight; these tests pin the contract
//! the serving plane relies on:
//!
//! * the LRU bound holds under concurrent `get_or_compile` / exec-cache
//!   traffic (ready entries beyond capacity are evicted, oldest first);
//! * in-flight entries are never evicted — a blocked leader's flight
//!   survives arbitrary eviction pressure and its waiters receive the
//!   leader's result, not a recompile;
//! * a re-request of an evicted key recompiles, still single-flight;
//! * the `compiles == misses` (and `execs == misses`) identity is
//!   preserved across evictions;
//! * eviction counters surface in the pool's merged `Metrics::report()`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use repro::backend::{
    Backend, BackendRegistry, CompileError, ExecReport, Mapped, MappedStats, Target,
};
use repro::bench::spec::{WorkloadCatalog, WorkloadSpec};
use repro::bench::workloads::Workload;
use repro::coordinator::{pool, CacheOutcome, CompileCache, ExecCache, ExecKey, Request, WorkloadKey};
use repro::ir::loopnest::ArrayData;

fn spec(name: &str, n: i64) -> WorkloadSpec {
    WorkloadCatalog::builtin().spec(name, n).expect("builtin")
}

/// A gemm spec under a different name — a distinct content address per
/// name, without needing new kernel constructors.
fn named_spec(name: &str) -> WorkloadSpec {
    let mut s = spec("gemm", 4);
    s.name = name.to_string();
    s
}

// ===================== a compile backend that can block ====================

struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    release: Mutex<bool>,
    release_cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            release: Mutex::new(false),
            release_cv: Condvar::new(),
        }
    }

    /// Called by the blocked pipeline: announce entry, then park until
    /// released.
    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut go = self.release.lock().unwrap();
        while !*go {
            go = self.release_cv.wait(go).unwrap();
        }
    }

    fn wait_entered(&self) {
        let mut e = self.entered.lock().unwrap();
        while !*e {
            e = self.entered_cv.wait(e).unwrap();
        }
    }

    fn release(&self) {
        *self.release.lock().unwrap() = true;
        self.release_cv.notify_all();
    }
}

/// Test backend: counts compiles, parks inside `compile` for the workload
/// named `block`, and deterministically fails everything (failures cache
/// exactly like artifacts, so nothing else is needed).
struct BlockingBackend {
    gate: Arc<Gate>,
    compiles: Arc<AtomicU64>,
}

fn partial_stats(wl: &Workload) -> MappedStats {
    MappedStats {
        workload: wl.name.clone(),
        n: wl.n,
        tool: None,
        opt: "-".into(),
        arch: "test".into(),
        n_loops: wl.n_loops,
        n_ops: 0,
        ii: None,
        unused_pes: None,
        max_ops_per_pe: None,
        latency: None,
        latency_overlapped: None,
    }
}

impl Backend for BlockingBackend {
    fn target(&self) -> Target {
        Target::Seq
    }

    fn name(&self) -> &'static str {
        "blocking-test"
    }

    fn compile(&self, wl: &Workload) -> Result<Box<dyn Mapped>, CompileError> {
        self.compiles.fetch_add(1, Ordering::SeqCst);
        if wl.name == "block" {
            self.gate.enter_and_wait();
        }
        Err(CompileError {
            stage: "test backend",
            message: format!("test backend rejects `{}`", wl.name),
            stats: partial_stats(wl),
        })
    }
}

// ============================== compile cache ==============================

#[test]
fn compile_lru_bound_respected_under_concurrent_traffic() {
    // the sequential backend compiles any gemm size instantly
    let cache = Arc::new(CompileCache::with_capacity(
        BackendRegistry::with_defaults(),
        4,
    ));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let c = cache.clone();
        handles.push(thread::spawn(move || {
            for _round in 0..3 {
                for n in 4..=11 {
                    let (r, _, _) = c.get_or_compile(&spec("gemm", n), Target::Seq);
                    assert!(r.is_ok());
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(cache.len() <= 4, "LRU bound violated: {} resident", cache.len());
    assert!(cache.stats.evictions() > 0, "8 keys through 4 slots must evict");
    assert_eq!(
        cache.stats.compiles(),
        cache.stats.misses(),
        "compiles == misses identity must survive evictions"
    );
    assert_eq!(
        cache.stats.hits() + cache.stats.misses() + cache.stats.waits(),
        4 * 3 * 8,
        "every request observed exactly one outcome"
    );
}

#[test]
fn in_flight_compiles_survive_eviction_pressure() {
    let gate = Arc::new(Gate::new());
    let compiles = Arc::new(AtomicU64::new(0));
    let mut registry = BackendRegistry::new();
    registry.register(Arc::new(BlockingBackend {
        gate: gate.clone(),
        compiles: compiles.clone(),
    }));
    let cache = Arc::new(CompileCache::with_capacity(registry, 1));

    // leader: claims the flight for `block` and parks inside the pipeline
    let block_spec = named_spec("block");
    let leader = {
        let c = cache.clone();
        let s = block_spec.clone();
        thread::spawn(move || c.get_or_compile(&s, Target::Seq).1)
    };
    gate.wait_entered();

    // eviction pressure around the blocked flight: capacity 1, so every
    // ready entry displaces the previous one — but never the in-flight slot
    for name in ["a", "b", "c", "d"] {
        let (r, o, _) = cache.get_or_compile(&named_spec(name), Target::Seq);
        assert!(r.is_err(), "test backend fails everything");
        assert_eq!(o, CacheOutcome::Miss);
        assert!(
            cache.len() <= 2,
            "bound = capacity + in-flight, got {}",
            cache.len()
        );
    }

    // a joiner arriving while the leader still blocks must wait, not lead
    let joiner = {
        let c = cache.clone();
        let s = block_spec.clone();
        thread::spawn(move || c.get_or_compile(&s, Target::Seq).1)
    };
    thread::sleep(Duration::from_millis(50));
    gate.release();
    assert_eq!(leader.join().unwrap(), CacheOutcome::Miss);
    assert_ne!(
        joiner.join().unwrap(),
        CacheOutcome::Miss,
        "the in-flight entry was evicted: the joiner recompiled"
    );
    assert_eq!(
        compiles.load(Ordering::SeqCst),
        1 + 4,
        "`block` ran the pipeline exactly once despite eviction pressure"
    );
    // the resolved result landed in the cache (and, being newest, survived)
    let (_, o, _) = cache.get_or_compile(&block_spec, Target::Seq);
    assert_eq!(o, CacheOutcome::Hit);
}

#[test]
fn recompile_after_eviction_is_single_flight() {
    let cache = Arc::new(CompileCache::with_capacity(
        BackendRegistry::with_defaults(),
        2,
    ));
    // fill and overflow: gemm n=4 gets evicted
    for n in 4..=6 {
        cache.get_or_compile(&spec("gemm", n), Target::Seq);
    }
    assert_eq!(cache.stats.evictions(), 1);
    // 8 threads race on the evicted key: exactly one recompile
    let compiles_before = cache.stats.compiles();
    let s = Arc::new(spec("gemm", 4));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let c = cache.clone();
        let s = s.clone();
        handles.push(thread::spawn(move || {
            let (r, _, _) = c.get_or_compile(&s, Target::Seq);
            assert!(r.is_ok());
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        cache.stats.compiles(),
        compiles_before + 1,
        "re-compile after eviction must still be single-flight"
    );
}

// ================================ exec cache ===============================

fn report(latency: u64) -> ExecReport {
    ExecReport {
        latency_cycles: latency,
        batch_cycles: latency,
        issued_ops: latency,
        occupancy: 1.0,
        outputs: ArrayData::new(),
        detail: "test".into(),
        seu_flips: 0,
    }
}

fn exec_key(fp: u64) -> ExecKey {
    ExecKey {
        workload: WorkloadKey {
            fingerprint: fp,
            n: 8,
            target: Target::Seq,
        },
        seed: 1,
        batch: 1,
    }
}

#[test]
fn exec_cache_in_flight_survives_eviction_and_stays_single_flight() {
    let cache = Arc::new(ExecCache::with_capacity(1));
    let gate = Arc::new(Gate::new());
    let runs = Arc::new(AtomicU64::new(0));

    let leader = {
        let c = cache.clone();
        let g = gate.clone();
        let r = runs.clone();
        thread::spawn(move || {
            let (res, o) = c.get_or_run(exec_key(0), || {
                r.fetch_add(1, Ordering::SeqCst);
                g.enter_and_wait();
                Ok(report(1))
            });
            assert!(res.is_ok());
            o
        })
    };
    gate.wait_entered();

    // hammer other keys through the 1-slot cache while key 0 is in flight
    for fp in 1..=4 {
        let (_, o) = cache.get_or_run(exec_key(fp), || Ok(report(fp)));
        assert_eq!(o, CacheOutcome::Miss);
        assert!(cache.len() <= 2, "bound = capacity + in-flight");
    }

    let joiner = {
        let c = cache.clone();
        thread::spawn(move || c.get_or_run(exec_key(0), || panic!("must join, not re-run")).1)
    };
    thread::sleep(Duration::from_millis(50));
    gate.release();
    assert_eq!(leader.join().unwrap(), CacheOutcome::Miss);
    assert_ne!(joiner.join().unwrap(), CacheOutcome::Miss);
    assert_eq!(runs.load(Ordering::SeqCst), 1, "key 0 executed exactly once");
    let (_, o) = cache.get_or_run(exec_key(0), || panic!("resolved entry is resident"));
    assert_eq!(o, CacheOutcome::Hit);
    assert_eq!(
        cache.stats.execs(),
        cache.stats.misses(),
        "execs == misses identity across evictions"
    );
    assert!(cache.stats.evictions() > 0);
}

// ===================== eviction counters reach the pool ====================

#[test]
fn pool_metrics_surface_eviction_counters() {
    let cache = Arc::new(CompileCache::with_capacity(
        BackendRegistry::with_defaults(),
        2,
    ));
    let exec = Arc::new(ExecCache::with_capacity(2));
    let catalog = Arc::new(WorkloadCatalog::builtin());
    let (tx, rx, handle) =
        pool::serve_with_caches(2, cache.clone(), exec.clone(), catalog);
    // 4 distinct compile keys through 2 slots; 8 distinct exec keys
    // (seed = request id) through 2 slots
    for i in 0..8u64 {
        let n = 4 + (i % 4) as i64;
        tx.send(Request::named(i, "gemm", n, Target::Seq, 1, false, i))
            .unwrap();
    }
    for _ in 0..8 {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    drop(tx);
    let m = handle.join();
    assert!(cache.len() <= 2 && exec.len() <= 2, "bounds hold at drain");
    assert!(
        m.exec_evictions > 0,
        "8 distinct exec keys through 2 slots must evict"
    );
    assert_eq!(m.compile_evictions, cache.stats.evictions());
    let report = m.report();
    assert!(report.contains("evictions: compile="), "{report}");
}
