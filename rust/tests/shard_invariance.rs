//! Integration: sharded caches (`coordinator::shard`). Shard selection is a
//! pure function of the workload fingerprint, so the shard count must be
//! *observationally invisible*: the same trace produces the same response
//! set and the same aggregate cache counters at `--shards 1` and
//! `--shards 8`, and concurrent compiles of distinct fingerprints routed to
//! different shards proceed concurrently instead of serializing on one
//! cache lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use repro::backend::{
    Backend, BackendRegistry, CompileError, Mapped, MappedStats, Target,
};
use repro::bench::spec::{WorkloadCatalog, WorkloadSpec};
use repro::bench::workloads::Workload;
use repro::coordinator::pool::{self, PoolConfig};
use repro::coordinator::{CacheShards, Request, Response};

/// The serve trace shape: every builtin kernel round-robined over both
/// array targets with cycling batches, plus a replay tail so the exec
/// cache sees hits on every shard layout.
fn trace(n_req: usize) -> Vec<Request> {
    let catalog = WorkloadCatalog::builtin();
    let names = catalog.names();
    let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut t = Request::round_robin(&names, 8, n_req, 0);
    let replay: Vec<Request> = t
        .iter()
        .take(n_req / 2)
        .map(|r| Request {
            id: r.id + n_req as u64,
            ..r.clone()
        })
        .collect();
    t.extend(replay);
    t
}

/// Wall-normalized, id-sorted view of a response set.
fn normalized(mut responses: Vec<Response>) -> Vec<Response> {
    for r in &mut responses {
        r.wall = Duration::ZERO;
    }
    responses.sort_by_key(|r| r.id);
    responses
}

#[test]
fn shard_count_is_invisible_in_responses_and_counters() {
    let t = trace(24);
    // one worker pins the hit/miss assignment; the shard count is the only
    // variable between the two runs
    let (_, m1, r1) = pool::run_trace_sharded(1, 1, &t, PoolConfig::default());
    let (_, m8, r8) = pool::run_trace_sharded(1, 8, &t, PoolConfig::default());

    let (r1, r8) = (normalized(r1), normalized(r8));
    assert_eq!(r1.len(), r8.len());
    for (a, b) in r1.iter().zip(&r8) {
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "response records must not depend on the shard count"
        );
    }
    for (one, eight, what) in [
        (m1.served, m8.served, "served"),
        (m1.failed, m8.failed, "failed"),
        (m1.cache_hits, m8.cache_hits, "cache_hits"),
        (m1.cache_misses, m8.cache_misses, "cache_misses"),
        (m1.exec_hits, m8.exec_hits, "exec_hits"),
        (m1.exec_misses, m8.exec_misses, "exec_misses"),
        (m1.instantiations, m8.instantiations, "instantiations"),
        (m1.symbolic_hits, m8.symbolic_hits, "symbolic_hits"),
        (m1.symbolic_compiles, m8.symbolic_compiles, "symbolic_compiles"),
        (m1.compile_evictions, m8.compile_evictions, "compile_evictions"),
        (m1.exec_evictions, m8.exec_evictions, "exec_evictions"),
    ] {
        assert_eq!(one, eight, "{what} diverged between 1 and 8 shards");
    }
    // only the sharded plane emits per-shard lines
    assert!(m1.shards().len() <= 1, "single shard plane");
    assert!(m8.shards().len() > 1, "requests spread over several shards");
    let shard_total: u64 = m8.shards().iter().map(|s| s.served + s.failed).sum();
    assert_eq!(shard_total, m8.served + m8.failed, "per-shard lines cover every request");
}

#[test]
fn aggregate_counters_match_the_single_cache_exactly() {
    let t = trace(24);
    let run = |n_shards: usize| {
        let shards = Arc::new(CacheShards::new(n_shards));
        let (tx, rx, handle) = pool::serve_sharded(
            1,
            shards.clone(),
            Arc::new(WorkloadCatalog::builtin()),
            PoolConfig::default(),
        );
        for r in &t {
            tx.send(r.clone()).expect("pool alive");
        }
        let responses: Vec<Response> =
            (0..t.len()).map(|_| rx.recv().expect("response")).collect();
        drop(tx);
        handle.join();
        (shards.aggregate(), responses)
    };
    let (a1, _) = run(1);
    let (a8, _) = run(8);
    assert_eq!(a1, a8, "summing counters over shards reproduces the single cache");
    assert_eq!(
        a8.misses,
        a8.compiles + a8.instantiations,
        "the single-flight identity holds in aggregate: {a8:?}"
    );
    assert_eq!(a8.execs, a8.exec_misses, "exec identity in aggregate: {a8:?}");
    // exec-cache hits (the replay tail) never touch the compile cache, so
    // compile outcomes count once per exec miss, exec outcomes once per req
    assert_eq!(
        a8.hits + a8.misses + a8.waits,
        a8.exec_misses,
        "every exec miss observed exactly one compile-cache outcome: {a8:?}"
    );
    assert_eq!(
        a8.exec_hits + a8.exec_misses + a8.exec_waits,
        t.len() as u64,
        "every request observed exactly one exec-cache outcome: {a8:?}"
    );
}

// ================= distinct fingerprints on distinct shards ================

struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    release: Mutex<bool>,
    release_cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            release: Mutex::new(false),
            release_cv: Condvar::new(),
        }
    }

    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut go = self.release.lock().unwrap();
        while !*go {
            go = self.release_cv.wait(go).unwrap();
        }
    }

    fn wait_entered(&self) {
        let mut e = self.entered.lock().unwrap();
        while !*e {
            e = self.entered_cv.wait(e).unwrap();
        }
    }

    fn release(&self) {
        *self.release.lock().unwrap() = true;
        self.release_cv.notify_all();
    }
}

/// Seq backend that parks inside `compile` for every workload with a
/// registered gate (and fails everything, which caches like any artifact).
struct GatedBackend {
    gates: HashMap<String, Arc<Gate>>,
    compiles: Arc<AtomicU64>,
}

impl Backend for GatedBackend {
    fn target(&self) -> Target {
        Target::Seq
    }

    fn name(&self) -> &'static str {
        "gated-test"
    }

    fn compile(&self, wl: &Workload) -> Result<Box<dyn Mapped>, CompileError> {
        self.compiles.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = self.gates.get(&wl.name) {
            gate.enter_and_wait();
        }
        Err(CompileError {
            stage: "test backend",
            message: format!("test backend rejects `{}`", wl.name),
            stats: MappedStats {
                workload: wl.name.clone(),
                n: wl.n,
                tool: None,
                opt: "-".into(),
                arch: "test".into(),
                n_loops: wl.n_loops,
                n_ops: 0,
                ii: None,
                unused_pes: None,
                max_ops_per_pe: None,
                latency: None,
                latency_overlapped: None,
            },
        })
    }
}

fn renamed_spec(name: &str) -> WorkloadSpec {
    let mut s = WorkloadCatalog::builtin().spec("gemm", 4).expect("builtin");
    s.name = name.to_string();
    s
}

#[test]
fn distinct_fingerprints_on_distinct_shards_compile_concurrently() {
    const SHARDS: u64 = 4;
    // pick two workload names whose fingerprints land on different shards —
    // shard selection is fingerprint % S, so probe names until two differ
    let mut picked: Vec<(String, WorkloadSpec)> = Vec::new();
    for k in 0.. {
        let name = format!("block-{k}");
        let spec = renamed_spec(&name);
        if picked.is_empty()
            || spec.fingerprint() % SHARDS != picked[0].1.fingerprint() % SHARDS
        {
            picked.push((name, spec));
        }
        if picked.len() == 2 {
            break;
        }
    }
    let (name_a, spec_a) = picked[0].clone();
    let (name_b, spec_b) = picked[1].clone();

    let gate_a = Arc::new(Gate::new());
    let gate_b = Arc::new(Gate::new());
    let compiles = Arc::new(AtomicU64::new(0));
    let shards = {
        let (gate_a, gate_b, compiles) = (gate_a.clone(), gate_b.clone(), compiles.clone());
        CacheShards::with_registry(SHARDS as usize, move || {
            let mut r = BackendRegistry::new();
            r.register(Arc::new(GatedBackend {
                gates: HashMap::from([
                    (name_a.clone(), gate_a.clone()),
                    (name_b.clone(), gate_b.clone()),
                ]),
                compiles: compiles.clone(),
            }));
            r
        })
    };
    let (tx, rx, handle) = pool::serve_sharded(
        2,
        Arc::new(shards),
        Arc::new(WorkloadCatalog::builtin()),
        PoolConfig::default(),
    );

    // A parks inside its shard's compile flight…
    tx.send(Request::inline(0, spec_a, Target::Seq, 1, false, 0))
        .expect("pool alive");
    gate_a.wait_entered();
    // …and B — a different fingerprint on a different shard — must *enter*
    // its own compile while A is still blocked. This wait is the assertion:
    // if shards serialized distinct kernels, it would hang (and the harness
    // would time the test out).
    tx.send(Request::inline(1, spec_b, Target::Seq, 1, false, 0))
        .expect("pool alive");
    gate_b.wait_entered();
    assert_eq!(
        compiles.load(Ordering::SeqCst),
        2,
        "both compiles are in flight simultaneously"
    );

    gate_a.release();
    gate_b.release();
    let mut got: Vec<Response> = (0..2).map(|_| rx.recv().expect("response")).collect();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 2);
    assert!(got.iter().all(|r| r.error.is_some()), "test backend fails both");
    drop(tx);
    let m = handle.join();
    assert_eq!(m.failed, 2);
    assert!(m.shards().len() as u64 <= SHARDS);
}
