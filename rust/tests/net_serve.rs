//! Integration: the socket front-end (`coordinator::net`). Loopback-only —
//! every test binds 127.0.0.1:0 or a Unix socket under the cargo tmpdir.
//!
//! The contract under test is *path equivalence*: a JSONL trace pushed
//! through a real socket must produce exactly the records the file path
//! (`serve_jsonl_sharded`) produces for the same trace — served, shed,
//! expired, degraded and malformed-line records alike — because both fronts
//! share the same [`PoolSender`] admission edge. On top of that: many
//! concurrent connections keep the response identity and the aggregate
//! shard identities, and a client hangup cancels its pending requests via
//! the abort flag instead of burning worker time.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use repro::backend::{
    Backend, BackendRegistry, CompileError, Mapped, MappedStats, Target,
};
use repro::bench::spec::{WorkloadCatalog, WorkloadSpec};
use repro::bench::workloads::Workload;
use repro::coordinator::net::{self, ListenAddr};
use repro::coordinator::pool::PoolConfig;
use repro::coordinator::{wire, CacheShards, Metrics, Request};
use repro::util::json::Json;

// ============================ helpers ======================================

/// Canonicalize one output record for set comparison: responses are decoded,
/// wall-normalized and re-encoded through the wire layer (field order is
/// deterministic); line-error records (no `wall_us`, but a `line` field) are
/// kept verbatim.
fn canonical(line: &str) -> String {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("bad record {line}: {e}"));
    if j.get("line").is_some() {
        return line.to_string();
    }
    let mut r = wire::response_from_json(&j).unwrap_or_else(|e| panic!("{line}: {e}"));
    r.wall = Duration::ZERO;
    wire::response_to_json(&r).render()
}

fn canonical_set(text: &str) -> Vec<String> {
    let mut v: Vec<String> = text.lines().map(canonical).collect();
    v.sort();
    v
}

/// Drive a trace through the file/stdin front end.
fn file_records(
    trace: &str,
    workers: usize,
    shards: usize,
    config: PoolConfig,
) -> (Vec<String>, Metrics) {
    let mut out = Vec::new();
    let m = wire::serve_jsonl_sharded(
        &mut trace.as_bytes(),
        &mut out,
        workers,
        shards,
        Arc::new(WorkloadCatalog::builtin()),
        config,
    )
    .expect("jsonl serve");
    (canonical_set(&String::from_utf8(out).unwrap()), m)
}

/// Drive the same trace through a real TCP connection: write everything,
/// half-close, read records until the server closes the stream.
fn socket_records(
    trace: &str,
    workers: usize,
    shards: usize,
    config: PoolConfig,
) -> (Vec<String>, Metrics) {
    let server = net::serve(
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        workers,
        Arc::new(CacheShards::new(shards)),
        Arc::new(WorkloadCatalog::builtin()),
        config,
    )
    .expect("bind loopback");
    let addr = match server.local_addr() {
        ListenAddr::Tcp(a) => a.clone(),
        other => panic!("expected tcp, got {other}"),
    };
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.write_all(trace.as_bytes()).expect("send trace");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read responses");
    let m = server.shutdown();
    (canonical_set(&text), m)
}

/// A mixed trace covering every record family the wire emits: plain serves
/// on all three targets, an exec-cache replay, a blank line, a garbage line
/// (error record without id), a bad-version line (error record *with* id),
/// an admission-expired request, a degraded fallback and a plain failure.
fn mixed_trace() -> String {
    let reqs = vec![
        Request::named(0, "gemm", 8, Target::Tcpa, 1, false, 1),
        Request::named(1, "atax", 8, Target::Cgra, 2, false, 2),
        Request::named(2, "gemm", 12, Target::Tcpa, 1, true, 1),
        Request::named(3, "gesummv", 8, Target::Seq, 1, false, 3),
        Request::named(4, "gemm", 8, Target::Tcpa, 1, false, 1), // replay of id 0
        Request::named(5, "gemm", 8, Target::Tcpa, 1, false, 0).with_deadline_ms(0),
        Request::named(6, "gemm", 64, Target::Cgra, 1, false, 1).with_fallback(),
        Request::named(7, "gemm", 64, Target::Cgra, 1, false, 1),
    ];
    let mut lines: Vec<String> = reqs.iter().map(|r| wire::request_to_json(r).render()).collect();
    lines.push(String::new()); // blank: skipped, but counted in line numbers
    lines.push("definitely not json".into());
    lines.push(r#"{"v":99,"id":42,"workload":{"name":"gemm","n":8},"target":"tcpa"}"#.into());
    lines.join("\n") + "\n"
}

// ====================== byte equivalence with the file path ================

#[test]
fn socket_records_match_the_file_path_byte_for_byte() {
    let trace = mixed_trace();
    // one worker makes cache-flag assignment deterministic on both paths
    let (file, fm) = file_records(&trace, 1, 3, PoolConfig::default());
    let (sock, sm) = socket_records(&trace, 1, 3, PoolConfig::default());
    assert_eq!(file, sock, "socket and file front-ends must emit identical record sets");
    assert_eq!(file.len(), 10, "8 responses + 2 line-error records");

    // the record families all actually occurred
    let text = sock.join("\n");
    assert!(text.contains(r#""error_kind":"timeout""#), "{text}");
    assert!(text.contains(r#""degraded":true"#), "{text}");
    assert!(text.contains(r#""exec_cache_hit":true"#), "{text}");
    assert!(text.contains(r#""line":10"#), "garbage line keeps its file-path line number: {text}");
    let bad_version: Vec<&String> = sock.iter().filter(|l| l.contains(r#""line":11"#)).collect();
    assert_eq!(bad_version.len(), 1);
    assert!(bad_version[0].contains(r#""id":42"#), "recoverable id echoed: {}", bad_version[0]);

    // and the two fronts agree on the bookkeeping, not just the bytes
    for (f, s) in [
        (fm.served, sm.served),
        (fm.failed, sm.failed),
        (fm.timeouts, sm.timeouts),
        (fm.degraded, sm.degraded),
        (fm.shed, sm.shed),
        (fm.cache_hits, sm.cache_hits),
        (fm.cache_misses, sm.cache_misses),
    ] {
        assert_eq!(f, s, "file={fm:?}\nsock={sm:?}");
    }
    assert_eq!(sm.shed + sm.failed + sm.served, 8, "admission identity over the socket");
    assert_eq!(sm.conns_accepted, 1);
    assert_eq!(sm.conns_closed, 1, "half-close then drain is a clean end-of-stream");
    assert_eq!(sm.conns_aborted, 0);
}

#[test]
fn socket_sheds_exactly_like_the_file_path() {
    let reqs: Vec<String> = (0..4)
        .map(|i| {
            wire::request_to_json(&Request::named(i, "gemm", 8, Target::Tcpa, 1, false, i)).render()
        })
        .collect();
    let trace = reqs.join("\n") + "\n";
    let config = PoolConfig {
        queue_cap: Some(0),
        ..PoolConfig::default()
    };
    let (file, fm) = file_records(&trace, 2, 2, config.clone());
    let (sock, sm) = socket_records(&trace, 2, 2, config);
    assert_eq!(file, sock);
    assert_eq!(sm.shed, 4, "a zero-capacity queue sheds everything");
    assert_eq!(fm.shed, sm.shed);
    assert!(sock.iter().all(|l| l.contains(r#""error_kind":"shed""#)), "{sock:?}");
}

#[cfg(feature = "fault-injection")]
#[test]
fn socket_matches_the_file_path_under_fault_injection() {
    use repro::coordinator::{FaultPlan, FaultSite};
    // fault decisions are a pure hash of (seed, site, request id), so both
    // fronts see the same storm; one worker keeps retry order deterministic
    let plan = || {
        Some(Arc::new(
            FaultPlan::new(5)
                .with_rate(FaultSite::CompilePanic, 300)
                .with_rate(FaultSite::ExecPanic, 200),
        ))
    };
    let reqs: Vec<String> = (0..16)
        .map(|i| {
            let t = if i % 2 == 0 { Target::Tcpa } else { Target::Cgra };
            let name = if i % 3 == 0 { "atax" } else { "gemm" };
            wire::request_to_json(&Request::named(i, name, 8, t, 1, false, i)).render()
        })
        .collect();
    let trace = reqs.join("\n") + "\n";
    let config = |f| PoolConfig {
        faults: f,
        ..PoolConfig::default()
    };
    let (file, fm) = file_records(&trace, 1, 2, config(plan()));
    let (sock, sm) = socket_records(&trace, 1, 2, config(plan()));
    assert_eq!(file, sock, "fault-typed records must match across fronts");
    assert!(fm.poisoned_flights > 0, "the storm must fire (seed 5)");
    assert_eq!(fm.failed, sm.failed);
    assert_eq!(fm.retries, sm.retries);
    assert_eq!(sm.shed + sm.failed + sm.served, 16);
}

// ====================== many concurrent connections ========================

#[test]
fn concurrent_connections_keep_the_identities() {
    let shards = Arc::new(CacheShards::new(4));
    let server = net::serve(
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        4,
        shards.clone(),
        Arc::new(WorkloadCatalog::builtin()),
        PoolConfig::default(),
    )
    .expect("bind loopback");
    let addr = match server.local_addr() {
        ListenAddr::Tcp(a) => a.clone(),
        other => panic!("expected tcp, got {other}"),
    };

    const CLIENTS: u64 = 8;
    const PER_CLIENT: u64 = 12;
    let names = ["gemm", "atax", "gesummv", "mvt"];
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let mut stream = TcpStream::connect(&addr).expect("connect");
            let mut expected = Vec::new();
            for i in 0..PER_CLIENT {
                let id = c * 1000 + i;
                let name = names[(c + i) as usize % names.len()];
                let t = if i % 2 == 0 { Target::Tcpa } else { Target::Cgra };
                let req = Request::named(id, name, 8, t, 1 + i % 2, false, c);
                stream
                    .write_all((wire::request_to_json(&req).render() + "\n").as_bytes())
                    .expect("send");
                expected.push(id);
            }
            stream.shutdown(Shutdown::Write).expect("half-close");
            let reader = BufReader::new(stream);
            let mut got: Vec<u64> = reader
                .lines()
                .map(|l| {
                    let l = l.expect("read");
                    let r = wire::response_from_json(&Json::parse(&l).unwrap())
                        .unwrap_or_else(|e| panic!("{l}: {e}"));
                    assert!(r.error.is_none(), "{:?}", r.error);
                    r.id
                })
                .collect();
            got.sort_unstable();
            assert_eq!(got, expected, "each connection sees exactly its own ids");
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }

    let m = server.shutdown();
    let total = CLIENTS * PER_CLIENT;
    assert_eq!(m.served, total);
    assert_eq!(m.shed + m.failed + m.served, total, "admission identity");
    assert_eq!(m.conns_accepted, CLIENTS);
    assert_eq!(m.conns_closed, CLIENTS);
    assert_eq!(m.conns_aborted, 0);

    // aggregate cache identities across the shard set
    let a = shards.aggregate();
    assert_eq!(
        a.misses,
        a.compiles + a.instantiations,
        "aggregate single-flight identity must survive sharding: {a:?}"
    );
    assert_eq!(a.execs, a.exec_misses, "exec identity: {a:?}");
    // an exec-cache hit short-circuits the pipeline without touching the
    // compile cache, so compile outcomes count once per exec miss
    assert_eq!(
        a.hits + a.misses + a.waits,
        a.exec_misses,
        "every exec miss observed exactly one compile-cache outcome: {a:?}"
    );
    assert_eq!(
        a.exec_hits + a.exec_misses + a.exec_waits,
        total,
        "every request observed exactly one exec-cache outcome: {a:?}"
    );
    assert_eq!(m.cache_misses, a.misses, "worker counters agree with shard counters");
}

// ============================ unix sockets =================================

fn tmp_sock(name: &str) -> PathBuf {
    Path::new(env!("CARGO_TARGET_TMPDIR")).join(name)
}

#[test]
fn unix_socket_roundtrip_and_cleanup() {
    let path = tmp_sock("repro-roundtrip.sock");
    let server = net::serve(
        &ListenAddr::Unix(path.clone()),
        2,
        Arc::new(CacheShards::new(2)),
        Arc::new(WorkloadCatalog::builtin()),
        PoolConfig::default(),
    )
    .expect("bind unix socket");

    let mut stream = UnixStream::connect(&path).expect("connect");
    for i in 0..3u64 {
        let req = Request::named(i, "gemm", 8, Target::Tcpa, 1, false, i);
        stream
            .write_all((wire::request_to_json(&req).render() + "\n").as_bytes())
            .expect("send");
    }
    stream.shutdown(Shutdown::Write).expect("half-close");
    let reader = BufReader::new(stream);
    let mut ids: Vec<u64> = reader
        .lines()
        .map(|l| {
            let l = l.expect("read");
            let r = wire::response_from_json(&Json::parse(&l).unwrap()).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            r.id
        })
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);

    let m = server.shutdown();
    assert_eq!(m.served, 3);
    assert_eq!((m.conns_accepted, m.conns_closed, m.conns_aborted), (1, 1, 0));
    assert!(!path.exists(), "shutdown removes the socket file");
}

// ========================= hangup cancellation =============================

/// `enter_and_wait` announces the compile entered and parks until released —
/// the deterministic handshake the eviction tests use.
struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    release: Mutex<bool>,
    release_cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            release: Mutex::new(false),
            release_cv: Condvar::new(),
        }
    }

    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut go = self.release.lock().unwrap();
        while !*go {
            go = self.release_cv.wait(go).unwrap();
        }
    }

    fn wait_entered(&self) {
        let mut e = self.entered.lock().unwrap();
        while !*e {
            e = self.entered_cv.wait(e).unwrap();
        }
    }

    fn release(&self) {
        *self.release.lock().unwrap() = true;
        self.release_cv.notify_all();
    }
}

/// Seq-target test backend: parks in `compile` for the workload named
/// `block`, takes a couple of milliseconds for everything else (so a raised
/// abort flag observably beats the queue), and fails every compile — cached
/// failures are all the pipeline the test needs.
struct SlowBackend {
    gate: Arc<Gate>,
    compiles: Arc<AtomicU64>,
}

impl Backend for SlowBackend {
    fn target(&self) -> Target {
        Target::Seq
    }

    fn name(&self) -> &'static str {
        "slow-test"
    }

    fn compile(&self, wl: &Workload) -> Result<Box<dyn Mapped>, CompileError> {
        self.compiles.fetch_add(1, Ordering::SeqCst);
        if wl.name == "block" {
            self.gate.enter_and_wait();
        } else {
            thread::sleep(Duration::from_millis(2));
        }
        Err(CompileError {
            stage: "test backend",
            message: format!("test backend rejects `{}`", wl.name),
            stats: MappedStats {
                workload: wl.name.clone(),
                n: wl.n,
                tool: None,
                opt: "-".into(),
                arch: "test".into(),
                n_loops: wl.n_loops,
                n_ops: 0,
                ii: None,
                unused_pes: None,
                max_ops_per_pe: None,
                latency: None,
                latency_overlapped: None,
            },
        })
    }
}

/// A gemm spec under an arbitrary name: a distinct content address per name.
fn renamed_spec(name: &str) -> WorkloadSpec {
    let mut s = WorkloadCatalog::builtin().spec("gemm", 4).expect("builtin");
    s.name = name.to_string();
    s
}

fn wait_until(timeout: Duration, f: impl Fn() -> bool) -> bool {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return true;
        }
        thread::sleep(Duration::from_millis(5));
    }
    f()
}

#[test]
fn client_hangup_cancels_its_pending_requests() {
    const FILLERS: u64 = 20;
    let gate = Arc::new(Gate::new());
    let compiles = Arc::new(AtomicU64::new(0));
    let shards = {
        let gate = gate.clone();
        let compiles = compiles.clone();
        CacheShards::with_registry(1, move || {
            let mut r = BackendRegistry::new();
            r.register(Arc::new(SlowBackend {
                gate: gate.clone(),
                compiles: compiles.clone(),
            }));
            r
        })
    };
    let path = tmp_sock("repro-hangup.sock");
    let server = net::serve(
        &ListenAddr::Unix(path.clone()),
        1, // a single worker serializes the queue behind the blocked compile
        Arc::new(shards),
        Arc::new(WorkloadCatalog::builtin()),
        PoolConfig::default(),
    )
    .expect("bind unix socket");

    let mut stream = UnixStream::connect(&path).expect("connect");
    let head = Request::inline(0, renamed_spec("block"), Target::Seq, 1, false, 0);
    stream
        .write_all((wire::request_to_json(&head).render() + "\n").as_bytes())
        .expect("send head");
    for i in 0..FILLERS {
        let req = Request::inline(1 + i, renamed_spec(&format!("w{i}")), Target::Seq, 1, false, 0);
        stream
            .write_all((wire::request_to_json(&req).render() + "\n").as_bytes())
            .expect("send filler");
    }

    // the worker is now parked inside `block`'s compile with 20 queued
    // requests behind it; the client vanishes without reading a byte
    gate.wait_entered();
    drop(stream);
    gate.release();

    // the head's response write hits the dead peer and raises the abort
    // flag — observable through the live connection counters
    let counters = server.counters().clone();
    assert!(
        wait_until(Duration::from_secs(10), || counters
            .aborted
            .load(Ordering::SeqCst)
            == 1),
        "the write to the hung-up peer must raise the abort"
    );

    let m = server.shutdown();
    let total = 1 + FILLERS;
    assert_eq!((m.conns_accepted, m.conns_closed, m.conns_aborted), (1, 0, 1));
    assert!(
        m.cancelled >= 1,
        "queued requests behind the hangup must cancel: {}",
        m.report()
    );
    assert!(
        compiles.load(Ordering::SeqCst) < total,
        "cancellation must skip at least one compile ({} of {total} ran)",
        compiles.load(Ordering::SeqCst)
    );
    assert_eq!(
        m.cancelled + compiles.load(Ordering::SeqCst),
        total,
        "every request either compiled or was cancelled"
    );
    assert!(m.cancelled <= m.timeouts, "cancelled is a subset of timeouts");
    assert_eq!(m.shed + m.failed + m.served, total, "identity holds through the hangup");
    assert_eq!(m.served, 0, "the test backend fails everything it does run");
}
