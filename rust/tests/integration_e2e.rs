//! End-to-end: XLA golden model (JAX/Pallas-lowered HLO via PJRT) vs both
//! cycle-accurate simulators, through the coordinator — all three layers
//! composing. Uses the artifacts from `make artifacts` when present and the
//! hermetic interpreter fallback otherwise.

use repro::bench::harness;
use repro::bench::spec::WorkloadCatalog;
use repro::bench::workloads::{build, inputs, BenchId};
use repro::coordinator::{Request, Session, Target};
use repro::ir::op::values_close;
use repro::runtime::golden::{GoldenService, GoldenSource};

#[test]
fn golden_vs_simulators_all_benchmarks() {
    let mut session = Session::new();
    for id in BenchId::ALL {
        for target in [Target::Tcpa, Target::Cgra] {
            let resp = session.handle(&Request::named(0, id.name(), 8, target, 1, true, 99));
            assert!(
                resp.error.is_none(),
                "{} on {:?}: {:?}",
                id.name(),
                target,
                resp.error
            );
            assert_eq!(
                resp.validated,
                Some(true),
                "{} on {:?} failed golden validation",
                id.name(),
                target
            );
        }
    }
}

#[test]
fn xla_golden_used_when_artifacts_present() {
    let mut svc = GoldenService::new();
    let spec = WorkloadCatalog::builtin().spec("gemm", 8).unwrap();
    let ins = inputs(BenchId::Gemm, 8, 1);
    let (_, src) = svc.run(&spec, &ins).unwrap();
    if std::path::Path::new("artifacts/MANIFEST").exists() {
        assert_eq!(src, GoldenSource::Xla, "artifacts exist but XLA not used");
    } else {
        eprintln!("artifacts missing; interpreter fallback exercised");
        assert_eq!(src, GoldenSource::Interpreter);
    }
}

#[test]
fn golden_matches_both_ir_interpreters() {
    let mut svc = GoldenService::new();
    let cat = WorkloadCatalog::builtin();
    for id in BenchId::ALL {
        let n = 8;
        let wl = build(id, n);
        let ins = inputs(id, n, 17);
        let (golden, _) = svc.run(&cat.spec(id.name(), n).unwrap(), &ins).unwrap();
        let nest_ref = wl.reference_nest(&ins);
        let pra_ref = wl.reference_pra(&ins);
        for name in wl.output_names() {
            for (which, other) in [("nest", &nest_ref), ("pra", &pra_ref)] {
                for (a, b) in golden[&name].iter().zip(other[&name].iter()) {
                    assert!(
                        values_close(id.dtype(), *a, *b),
                        "{}/{name} golden vs {which}: {a} vs {b}",
                        id.name()
                    );
                }
            }
        }
    }
}

#[test]
fn harness_validate_all_benchmarks() {
    for id in BenchId::ALL {
        harness::validate(id, 8, 5).unwrap_or_else(|e| panic!("{}: {e}", id.name()));
    }
}

#[test]
fn paper_size_gemm_validates_against_xla() {
    // the paper's GEMM size (N = 20) end to end
    let lines = harness::validate(BenchId::Gemm, 20, 123).expect("validate n=20");
    assert_eq!(lines.len(), 2);
}
