//! Architecture exploration: sweep custom CGRA and TCPA configurations and
//! report the paper's trade-offs (II, latency, area, power) — the ablation
//! the §VI discussion argues about (border memory, multi-hop interconnect,
//! FU complements, FIFO budgets).
//!
//! ```sh
//! cargo run --release --example custom_architecture
//! ```

use repro::bench::harness::{map_cgra_row, map_turtle};
use repro::bench::toolchains::{rows_for, RowSpec, Tool};
use repro::bench::workloads::{build, BenchId};
use repro::cgra::arch::{CgraArch, MemAccess};
use repro::ppa::area::{cgra_area, tcpa_area};
use repro::ppa::power::PowerModel;
use repro::tcpa::arch::TcpaArch;
use repro::util::table::Table;

fn cgra_variants() -> Vec<CgraArch> {
    let mut borders = CgraArch::classical(4, 4);
    borders.name = "classical+borders".into();
    borders.mem_access = MemAccess::Borders;
    let mut fat = CgraArch::classical(4, 4);
    fat.name = "classical+16regs".into();
    fat.route_regs = 16;
    vec![
        CgraArch::classical(4, 4),
        CgraArch::hycube(4, 4),
        borders,
        fat,
    ]
}

fn main() {
    let id = BenchId::Gesummv;
    let wl = build(id, id.paper_size());
    let base = rows_for(wl.n_loops, 4, 4)
        .into_iter()
        .find(|s| s.tool == Tool::Morpher)
        .unwrap();

    println!("== CGRA variants on {} (N={}) ==", id.name(), id.paper_size());
    let mut t = Table::new(vec!["Architecture", "II", "latency", "kLUT", "est. W"]);
    let cref = cgra_area(&CgraArch::classical(4, 4));
    let tref = tcpa_area(&TcpaArch::paper(4, 4));
    let pm = PowerModel::calibrated(&cref, &tref);
    for arch in cgra_variants() {
        let spec = RowSpec {
            arch: arch.clone(),
            ..base.clone()
        };
        let row = map_cgra_row(&wl, &spec);
        let area = cgra_area(&arch);
        t.row(vec![
            arch.name.clone(),
            row.ii.map(|x| x.to_string()).unwrap_or("-".into()),
            row.latency.map(|x| x.to_string()).unwrap_or("-".into()),
            format!("{:.1}", area.total.lut / 1000.0),
            format!("{:.2}", pm.watts(&area)),
        ]);
    }
    println!("{}", t.render());

    println!("== TCPA variants ==");
    let mut t = Table::new(vec![
        "Architecture", "II", "first PE", "last PE", "kLUT", "est. W",
    ]);
    let mut lean = TcpaArch::paper(4, 4);
    lean.name = "tcpa-lean (1 add, 1 copy)".into();
    lean.fus.adders = 1;
    lean.fus.copy_units = 1;
    let mut fat = TcpaArch::paper(4, 4);
    fat.name = "tcpa-fat (4 add, 2 mul)".into();
    fat.fus.adders = 4;
    fat.fus.multipliers = 2;
    let mut small_fifo = TcpaArch::paper(4, 4);
    small_fifo.name = "tcpa-smallfifo (64 words)".into();
    small_fifo.fifo_words = 64;
    for arch in [TcpaArch::paper(4, 4), lean, fat, small_fifo] {
        let tr = map_turtle(&wl, &arch);
        let area = tcpa_area(&arch);
        match tr.error {
            None => t.row(vec![
                arch.name.clone(),
                tr.ii.to_string(),
                tr.latency_first.to_string(),
                tr.latency_last.to_string(),
                format!("{:.1}", area.total.lut / 1000.0),
                format!("{:.2}", pm.watts(&area)),
            ]),
            Some(e) => t.row(vec![
                arch.name.clone(),
                format!("FAIL: {e}"),
                "-".into(),
                "-".into(),
                format!("{:.1}", area.total.lut / 1000.0),
                format!("{:.2}", pm.watts(&area)),
            ]),
        }
    }
    println!("{}", t.render());
}
