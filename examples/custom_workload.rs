//! Open workload API: define a kernel that is *not* in the PolyBench
//! builtin set — a 2-D Jacobi-style 5-point stencil — with the
//! [`WorkloadBuilder`], register it in the [`WorkloadCatalog`], and serve
//! it through the coordinator pool on both array targets (TCPA and CGRA),
//! golden-validated, with the second submission hitting the
//! content-addressed compile cache.
//!
//! The same kernel also round-trips the JSON wire protocol: the inline-spec
//! request printed at the end is exactly what `repro serve --requests -`
//! accepts on stdin.
//!
//! ```sh
//! cargo run --release --example custom_workload
//! ```

use std::sync::Arc;

use repro::bench::spec::{WorkloadBuilder, WorkloadCatalog, WorkloadSpec};
use repro::coordinator::{pool, wire, CompileCache, Request, Target, WorkloadKey};
use repro::ir::affine::AffineMap;
use repro::ir::loopnest::{idx, idx_plus, ArrayKind, Expr, LoopNest, NestBuilder};
use repro::ir::op::{Dtype, OpKind};
use repro::ir::pra::{Pra, PraBuilder};
use repro::ir::space::CondSpace;

/// The CGRA view: a rectangular 2-deep nest over the (n−2)×(n−2) interior,
/// `S[i+1,j+1] = A[i+1,j+1] + A[i,j+1] + A[i+2,j+1] + A[i+1,j] + A[i+1,j+2]`
/// (an unweighted Jacobi-style neighborhood sum — integer, so both views
/// agree exactly).
fn jacobi_nest(n: i64) -> LoopNest {
    let d = 2;
    let m = n - 2;
    NestBuilder::new("jacobi2d", Dtype::I32)
        .dim("i0", m)
        .dim("i1", m)
        .array("A", vec![n, n], ArrayKind::Input)
        .array("S", vec![n, n], ArrayKind::Output)
        .stmt(
            "S",
            vec![idx_plus(d, 0, 1), idx_plus(d, 1, 1)],
            Expr::bin(
                OpKind::Add,
                // center
                Expr::read(0, vec![idx_plus(d, 0, 1), idx_plus(d, 1, 1)]),
                Expr::bin(
                    OpKind::Add,
                    Expr::bin(
                        OpKind::Add,
                        // up / down
                        Expr::read(0, vec![idx(d, 0), idx_plus(d, 1, 1)]),
                        Expr::read(0, vec![idx_plus(d, 0, 2), idx_plus(d, 1, 1)]),
                    ),
                    Expr::bin(
                        OpKind::Add,
                        // left / right
                        Expr::read(0, vec![idx_plus(d, 0, 1), idx(d, 1)]),
                        Expr::read(0, vec![idx_plus(d, 0, 1), idx_plus(d, 1, 2)]),
                    ),
                ),
            ),
        )
        .finish()
}

/// The TCPA view: the same stencil as a PRA over the interior space. Every
/// neighbor is an I/O-buffer read through its own affine address generator
/// (offsets into the full n×n array), the adds form a three-equation
/// reduction tree, and the output AG writes the interior of `S`.
fn jacobi_pra(n: i64) -> Pra {
    let m = n - 2;
    let ident_off = |r: i64, c: i64| AffineMap::new(vec![vec![1, 0], vec![0, 1]], vec![r, c]);
    let b = PraBuilder::new("jacobi2d", Dtype::I32, vec![m, m])
        .var("h")
        .var("v")
        .var("hv")
        .array("A", vec![n, n], ArrayKind::Input)
        .array("S", vec![n, n], ArrayKind::Output);
    let left = b.input("A", ident_off(1, 0));
    let right = b.input("A", ident_off(1, 2));
    let up = b.input("A", ident_off(0, 1));
    let down = b.input("A", ident_off(2, 1));
    let center = b.input("A", ident_off(1, 1));
    let (h0, v0, hv0) = (b.v0("h"), b.v0("v"), b.v0("hv"));
    b.eq("H", "h", OpKind::Add, vec![left, right], CondSpace::all())
        .eq("V", "v", OpKind::Add, vec![up, down], CondSpace::all())
        .eq("HV", "hv", OpKind::Add, vec![h0, v0], CondSpace::all())
        .out_eq(
            "Out",
            "S",
            ident_off(1, 1),
            OpKind::Add,
            vec![hv0, center],
            CondSpace::all(),
        )
        .finish()
}

/// The full spec: both views plus the deterministic input recipe. `n = 10`
/// gives an 8×8 interior — tiled 2×2 per PE on the paper's 4×4 arrays.
fn jacobi2d_spec(n: i64) -> WorkloadSpec {
    WorkloadBuilder::new("jacobi2d", n, Dtype::I32)
        .stage(jacobi_nest(n), jacobi_pra(n))
        .uniform_input("A", vec![n, n], 1, 10)
        .finish()
        .expect("jacobi2d spec")
}

fn main() {
    const N: i64 = 10;

    // 1. register the custom kernel next to the builtins
    let mut catalog = WorkloadCatalog::builtin();
    catalog.register("jacobi2d", jacobi2d_spec);
    println!("catalog: {}", catalog.names().join(", "));

    let spec = jacobi2d_spec(N);
    println!(
        "jacobi2d spec: fingerprint {:016x}, {} bytes of canonical JSON\n",
        spec.fingerprint(),
        spec.to_json().render().len()
    );

    // 2. serve it through the pool on both array targets, twice per target —
    //    the repeat must hit the content-addressed compile cache
    let cache = Arc::new(CompileCache::new());
    let (tx, rx, handle) = pool::serve_with(2, cache.clone(), Arc::new(catalog));
    let mut id = 0u64;
    for _round in 0..2 {
        for target in [Target::Tcpa, Target::Cgra] {
            tx.send(Request::named(id, "jacobi2d", N, target, 2, true, 42))
                .unwrap();
            id += 1;
        }
    }
    let mut responses: Vec<_> = (0..id).map(|_| rx.recv().unwrap()).collect();
    drop(tx);
    let metrics = handle.join();
    responses.sort_by_key(|r| r.id);
    for r in &responses {
        println!(
            "[{}] {:<8} n={} {:<5} batch={} latency={} batch_cycles={} \
             validated={:?} cache_hit={}{}",
            r.id,
            r.workload,
            r.n,
            r.target.name(),
            r.batch,
            r.latency_cycles,
            r.batch_cycles,
            r.validated,
            r.cache_hit,
            r.error
                .as_ref()
                .map(|e| format!(" ERROR: {e}"))
                .unwrap_or_default()
        );
    }
    println!(
        "\ncompiles: {} (2 targets x 1 kernel), cache hits: {}",
        cache.stats.compiles(),
        cache.stats.hits()
    );
    println!("{}\n", metrics.report());

    // 3. the same kernel as a wire-protocol record: an *inline* spec request
    //    content-addresses to the very same artifacts the named requests
    //    compiled above
    let inline = Request::inline(99, spec.clone(), Target::Tcpa, 1, false, 42);
    let line = wire::request_to_json(&inline).render();
    println!(
        "inline JSONL request ({} bytes; feed it to `repro serve --requests -`):",
        line.len()
    );
    println!("{}...", &line[..line.len().min(160)]);
    println!(
        "inline key {} == named key {}",
        WorkloadKey::of(&spec, Target::Tcpa),
        WorkloadKey::of(
            &jacobi2d_spec(N),
            Target::Tcpa
        )
    );
}
