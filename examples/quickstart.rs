//! Quickstart: map one benchmark (GEMM) onto both architecture classes,
//! simulate cycle-accurately, validate the numerics, and print the paper's
//! headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use repro::bench::harness::{map_cgra_row, map_turtle};
use repro::bench::toolchains::{rows_for, Tool};
use repro::bench::workloads::{build, inputs, BenchId};
use repro::cgra::sim as cgra_sim;
use repro::ppa::area::{area_ratio, cgra_area, tcpa_area};
use repro::ppa::power::PowerModel;
use repro::tcpa::arch::TcpaArch;
use repro::tcpa::sim as tcpa_sim;

fn main() {
    let n = 8;
    let id = BenchId::Gemm;
    let wl = build(id, n);
    let ins = inputs(id, n, 42);
    let want = wl.reference_nest(&ins);

    // --- operation-centric: Morpher-profile mapping on the classical 4×4 ---
    let spec = rows_for(wl.n_loops, 4, 4)
        .into_iter()
        .find(|s| s.tool == Tool::Morpher)
        .unwrap();
    let row = map_cgra_row(&wl, &spec);
    println!(
        "CGRA  ({}): {} ops, II = {}, latency = {} cycles",
        spec.arch.name,
        row.n_ops,
        row.ii.unwrap(),
        row.latency.unwrap()
    );
    let (dfg, mapping) = &row.mappings[0];
    let sim = cgra_sim::simulate(dfg, mapping, &ins);
    assert_eq!(sim.outputs["D"], want["D"], "CGRA numerics must match");
    println!("      cycle-accurate sim: {} cycles, outputs match ✓", sim.cycles);

    // --- iteration-centric: TURTLE-flow compilation onto the 4×4 TCPA ---
    let arch = TcpaArch::paper(4, 4);
    let tr = map_turtle(&wl, &arch);
    println!(
        "TCPA  ({}): {} instruction slots, II = {}, first PE {} / last PE {} cycles",
        arch.name, tr.n_ops, tr.ii, tr.latency_first, tr.latency_last
    );
    let run = tcpa_sim::simulate_workload(&tr.configs, &arch, &ins).unwrap();
    assert_eq!(run.outputs["D"], want["D"], "TCPA numerics must match");
    println!(
        "      cycle-accurate sim: {} cycles, outputs match ✓",
        run.total_latency
    );

    // --- the paper's headline trade-off ---
    let carea = cgra_area(&spec.arch);
    let tarea = tcpa_area(&arch);
    let pm = PowerModel::calibrated(&carea, &tarea);
    println!(
        "\nspeedup (TCPA vs CGRA): {:.1}x | area ratio: {:.2}x | power ratio: {:.2}x",
        row.latency.unwrap() as f64 / run.total_latency as f64,
        area_ratio(&tarea, &carea),
        pm.watts(&tarea) / pm.watts(&carea),
    );
}
