//! Batched/overlapped kernel serving through the coordinator — the paper's
//! §V-A argument that for repeated invocations the TCPA's restart interval
//! (first-PE latency) matters more than the full drain, while the evaluated
//! CGRAs always drain completely between invocations.
//!
//! ```sh
//! cargo run --release --example batch_serving
//! ```

use repro::bench::workloads::BenchId;
use repro::coordinator::{Request, Session, Target};
use repro::util::table::Table;

fn main() {
    let mut session = Session::new();
    let mut t = Table::new(vec![
        "Benchmark", "batch", "CGRA cycles", "TCPA cycles (overlapped)",
        "TCPA throughput gain vs serial",
    ]);
    for id in [BenchId::Gemm, BenchId::Atax, BenchId::Trsm] {
        for batch in [1u64, 4, 16] {
            let cgra =
                session.handle(&Request::named(0, id.name(), 8, Target::Cgra, batch, false, 1));
            let tcpa =
                session.handle(&Request::named(1, id.name(), 8, Target::Tcpa, batch, false, 1));
            let serial = tcpa.latency_cycles * batch;
            let gain = if tcpa.batch_cycles > 0 {
                format!("{:.2}x", serial as f64 / tcpa.batch_cycles as f64)
            } else {
                "-".into()
            };
            t.row(vec![
                id.name().to_string(),
                batch.to_string(),
                cgra.batch_cycles.to_string(),
                tcpa.batch_cycles.to_string(),
                gain,
            ]);
        }
    }
    println!("{}", t.render());
    println!("{}", session.metrics.summary());
}
