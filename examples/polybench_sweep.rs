//! End-to-end driver (DESIGN.md §6, EXPERIMENTS.md): run every PolyBench
//! benchmark through the whole stack — loop-nest/PRA frontends, both mapping
//! stacks, both cycle-accurate simulators — and validate every output
//! against the XLA golden model loaded from `artifacts/` (falling back to
//! the reference interpreter when artifacts are absent).
//!
//! ```sh
//! make artifacts && cargo run --release --example polybench_sweep
//! ```

use std::time::Instant;

use repro::bench::workloads::BenchId;
use repro::coordinator::{Request, Session, Target};
use repro::util::table::Table;

fn main() {
    let mut session = Session::new();
    let mut t = Table::new(vec![
        "Benchmark", "N", "CGRA cycles", "TCPA cycles", "speedup", "validated",
    ]);
    let t0 = Instant::now();
    for id in BenchId::ALL {
        let n = 8;
        let cgra = session.handle(&Request::named(0, id.name(), n, Target::Cgra, 1, true, 7));
        let tcpa = session.handle(&Request::named(1, id.name(), n, Target::Tcpa, 1, true, 7));
        let speed = if tcpa.latency_cycles > 0 && cgra.latency_cycles > 0 {
            format!(
                "{:.1}x",
                cgra.latency_cycles as f64 / tcpa.latency_cycles as f64
            )
        } else {
            "-".into()
        };
        let validated = match (cgra.validated, tcpa.validated, &cgra.error, &tcpa.error) {
            (_, _, Some(e), _) => format!("CGRA err: {e}"),
            (_, _, _, Some(e)) => format!("TCPA err: {e}"),
            (Some(a), Some(b), _, _) => {
                if a && b {
                    "both ✓".into()
                } else {
                    format!("CGRA={a} TCPA={b}")
                }
            }
            _ => "-".into(),
        };
        t.row(vec![
            id.name().to_string(),
            n.to_string(),
            cgra.latency_cycles.to_string(),
            tcpa.latency_cycles.to_string(),
            speed,
            validated,
        ]);
    }
    println!("{}", t.render());
    println!("coordinator: {}", session.metrics.summary());
    println!("total wall time: {:?}", t0.elapsed());
}
