"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes; `assert_allclose` against
`ref.py` is the core correctness signal of the build path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, tiled

DIMS = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16])
DTYPES = st.sampled_from([np.int32, np.float32])


def rand(rng, dtype, *shape):
    if dtype == np.int32:
        return rng.integers(-9, 10, size=shape).astype(np.int32)
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(n=DIMS, k=DIMS, m=DIMS, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(n, k, m, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, dtype, n, k)
    b = rand(rng, dtype, k, m)
    got = np.asarray(tiled.matmul(a, b))
    want = np.asarray(a @ b)
    if dtype == np.int32:
        assert (got == want).all()
    else:
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=DIMS,
    m=DIMS,
    transpose=st.booleans(),
    dtype=DTYPES,
    seed=st.integers(0, 2**16),
)
def test_matvec_matches_ref(n, m, transpose, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, dtype, n, m)
    x = rand(rng, dtype, n if transpose else m)
    got = np.asarray(tiled.matvec(a, x, transpose=transpose))
    want = np.asarray((a.T if transpose else a) @ x)
    if dtype == np.int32:
        assert (got == want).all()
    else:
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=DIMS, m=DIMS, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_gesummv_kernel_matches_ref(n, m, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, dtype, n, m)
    b = rand(rng, dtype, n, m)
    x = rand(rng, dtype, m)
    got = np.asarray(tiled.gesummv(a, b, x))
    want = np.asarray(a @ x + b @ x)
    if dtype == np.int32:
        assert (got == want).all()
    else:
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block", [1, 2, 4])
def test_matmul_block_sizes(block):
    rng = np.random.default_rng(7)
    a = rand(rng, np.int32, 8, 8)
    b = rand(rng, np.int32, 8, 8)
    got = np.asarray(tiled.matmul(a, b, block=block))
    assert (got == np.asarray(a @ b)).all()


def test_trisolv_ref_solves():
    rng = np.random.default_rng(3)
    n = 12
    ltri = np.tril(rng.integers(1, 4, (n, n))).astype(np.float32) + 4.0 * np.eye(
        n, dtype=np.float32
    )
    b = rng.integers(1, 10, n).astype(np.float32)
    x = np.asarray(ref.trisolv(ltri, b))
    assert_allclose(ltri @ x, b, rtol=1e-4, atol=1e-4)


def test_trsm_ref_solves():
    rng = np.random.default_rng(4)
    n = 8
    ltri = np.tril(rng.integers(1, 4, (n, n))).astype(np.float32) + 4.0 * np.eye(
        n, dtype=np.float32
    )
    bmat = rng.integers(1, 10, (n, n)).astype(np.float32)
    x = np.asarray(ref.trsm(ltri, bmat))
    assert_allclose(ltri @ x, bmat, rtol=1e-4, atol=1e-4)
