"""Layer-2 correctness: the jit-able benchmark models vs the oracle, plus
AOT lowering sanity (HLO text is produced and mentions the entry point)."""

import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def _args(name, n=8):
    return model.example_args(name, n)


def test_gemm_model_matches_ref():
    a, b, c = _args("gemm")
    assert (np.asarray(model.gemm(a, b, c)) == np.asarray(ref.gemm(a, b, c))).all()


def test_atax_model_matches_ref():
    a, x = _args("atax")
    assert (np.asarray(model.atax(a, x)) == np.asarray(ref.atax(a, x))).all()


def test_gesummv_model_matches_ref():
    a, b, x = _args("gesummv")
    assert (
        np.asarray(model.gesummv(a, b, x)) == np.asarray(ref.gesummv(a, b, x))
    ).all()


def test_mvt_model_matches_ref():
    args = _args("mvt")
    z1, z2 = model.mvt(*args)
    w1, w2 = ref.mvt(*args)
    assert (np.asarray(z1) == np.asarray(w1)).all()
    assert (np.asarray(z2) == np.asarray(w2)).all()


def test_trisolv_model_solves():
    ltri, b = _args("trisolv")
    x = np.asarray(model.trisolv(ltri, b))
    assert_allclose(ltri @ x, b, rtol=1e-4, atol=1e-4)


def test_trsm_model_solves():
    ltri, bmat = _args("trsm")
    x = np.asarray(model.trsm(ltri, bmat))
    assert_allclose(ltri @ x, bmat, rtol=1e-3, atol=1e-3)


def test_aot_lowering_produces_hlo_text():
    text = aot.lower_one("gemm", 4)
    assert "ENTRY" in text and "main" in text
    assert len(text) > 100


def test_all_models_lower():
    for name in model.MODELS:
        text = aot.lower_one(name, 4)
        assert "ENTRY" in text, name
