"""Layer-2 JAX models: each benchmark as a jit-able function composed from
the Layer-1 Pallas kernels (the compute hot-spots) plus jnp glue.

These are the golden models: `aot.py` lowers them once to HLO text and the
rust runtime executes them via PJRT to validate every simulated CGRA/TCPA
run. Python never sits on the rust request path.
"""

import jax.numpy as jnp
from jax import lax

from .kernels import tiled


def gemm(a, b, c):
    """D = A·B + C via the tiled Pallas matmul."""
    return tiled.matmul(a, b) + c


def atax(a, x):
    """y = Aᵀ·(A·x): two Pallas matvecs chained (the workload's two stages)."""
    tmp = tiled.matvec(a, x)
    return tiled.matvec(a, tmp, transpose=True)


def gesummv(a, b, x):
    """y = A·x + B·x via the fused Pallas kernel."""
    return tiled.gesummv(a, b, x)


def mvt(a, y1, y2, x1, x2):
    """z1 = x1 + A·y1 ; z2 = x2 + Aᵀ·y2 (two independent Pallas matvecs)."""
    z1 = x1 + tiled.matvec(a, y1)
    z2 = x2 + tiled.matvec(a, y2, transpose=True)
    return z1, z2


def trisolv(l, b):
    """Forward substitution (inherently sequential recurrence — stays a
    lax.scan; the multiplicative hot-spot inside is a masked dot)."""
    l = jnp.asarray(l)
    b = jnp.asarray(b)
    n = l.shape[0]

    def step(x, i):
        mask = (jnp.arange(n) < i).astype(l.dtype)
        s = jnp.dot(l[i] * mask, x)
        xi = (b[i] - s) / l[i, i]
        return x.at[i].set(xi), None

    x, _ = lax.scan(step, jnp.zeros_like(b), jnp.arange(n))
    return x


def trsm(l, bmat):
    """L·X = B: trisolv vmapped over the independent RHS columns —
    the parallelism the TCPA exploits across its PE columns (§V-A)."""
    import jax

    solve = jax.vmap(lambda col: trisolv(l, col), in_axes=1, out_axes=1)
    return solve(bmat)


#: benchmark name → (function, input builder (n) → example args)
def example_args(name: str, n: int):
    import numpy as np

    rng = np.random.default_rng(0)
    i32 = lambda *s: rng.integers(1, 10, size=s).astype(np.int32)  # noqa: E731
    f32 = lambda *s: rng.integers(1, 10, size=s).astype(np.float32)  # noqa: E731

    if name == "gemm":
        return (i32(n, n), i32(n, n), i32(n, n))
    if name == "atax":
        return (i32(n, n), i32(n))
    if name == "gesummv":
        return (i32(n, n), i32(n, n), i32(n))
    if name == "mvt":
        return (i32(n, n), i32(n), i32(n), i32(n), i32(n))
    if name == "trisolv":
        ltri = np.tril(f32(n, n)) + 4.0 * np.eye(n, dtype=np.float32)
        return (ltri, f32(n))
    if name == "trsm":
        ltri = np.tril(f32(n, n)) + 4.0 * np.eye(n, dtype=np.float32)
        return (ltri, f32(n, n))
    raise ValueError(f"unknown benchmark {name}")


MODELS = {
    "gemm": gemm,
    "atax": atax,
    "gesummv": gesummv,
    "mvt": mvt,
    "trisolv": trisolv,
    "trsm": trsm,
}

#: AOT sizes: a small validation size plus the paper's evaluation size
AOT_SIZES = {
    "gemm": [8, 20],
    "atax": [8, 32],
    "gesummv": [8, 32],
    "mvt": [8, 32],
    "trisolv": [8, 32],
    "trsm": [8, 32],
}
