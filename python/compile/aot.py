"""AOT lowering: JAX models → HLO *text* artifacts for the rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``
Python runs ONCE at build time; the rust binary is self-contained afterwards.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str, n: int) -> str:
    fn = model.MODELS[name]
    args = model.example_args(name, n)
    # wrap so every model returns a tuple (unwrapped with to_tuple on rust side)
    def wrapped(*a):
        out = fn(*a)
        return out if isinstance(out, tuple) else (out,)

    lowered = jax.jit(wrapped).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--benches", default=",".join(model.MODELS.keys()))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name in args.benches.split(","):
        for n in model.AOT_SIZES[name]:
            text = lower_one(name, n)
            path = os.path.join(args.out_dir, f"{name}_n{n}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"{name}_n{n}.hlo.txt")
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "MANIFEST"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"{len(manifest)} artifacts")


if __name__ == "__main__":
    main()
