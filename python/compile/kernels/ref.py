"""Pure-jnp reference oracle for every benchmark (paper §V-A).

These are the ground-truth semantics the Pallas kernels (and transitively the
rust-side cycle-accurate simulators, via the AOT-lowered HLO) are validated
against. Integer benchmarks use i32 (bit-exact), the triangular solvers f32.
"""

import jax.numpy as jnp
from jax import lax


def gemm(a, b, c):
    """D = A·B + C (the paper's GEMM; C is preloaded into the accumulator)."""
    return jnp.dot(a, b, preferred_element_type=a.dtype) + c


def atax(a, x):
    """y = Aᵀ·(A·x)."""
    tmp = jnp.dot(a, x, preferred_element_type=a.dtype)
    return jnp.dot(a.T, tmp, preferred_element_type=a.dtype)


def gesummv(a, b, x):
    """y = A·x + B·x."""
    return jnp.dot(a, x, preferred_element_type=a.dtype) + jnp.dot(
        b, x, preferred_element_type=a.dtype
    )


def mvt(a, y1, y2, x1, x2):
    """z1 = x1 + A·y1 ; z2 = x2 + Aᵀ·y2."""
    z1 = x1 + jnp.dot(a, y1, preferred_element_type=a.dtype)
    z2 = x2 + jnp.dot(a.T, y2, preferred_element_type=a.dtype)
    return z1, z2


def trisolv(l, b):
    """Forward substitution: solve L·x = b for lower-triangular L (f32)."""
    l = jnp.asarray(l)
    b = jnp.asarray(b)
    n = l.shape[0]

    def step(x, i):
        mask = (jnp.arange(n) < i).astype(l.dtype)
        s = jnp.dot(l[i] * mask, x)
        xi = (b[i] - s) / l[i, i]
        return x.at[i].set(xi), None

    x0 = jnp.zeros_like(b)
    x, _ = lax.scan(step, x0, jnp.arange(n))
    return x


def trsm(l, bmat):
    """Solve L·X = B column-by-column (N right-hand sides, f32)."""
    n = l.shape[0]
    return jnp.stack([trisolv(l, bmat[:, j]) for j in range(n)], axis=1)
