"""Layer-1 Pallas kernels: the benchmark compute hot-spots as tiled kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
iteration-centric insight — assign whole *tiles* of iterations to one PE and
keep reused data local — maps to Pallas as BlockSpec blocks resident in VMEM
(the scratchpad analog of the TCPA register file + feedback FIFOs) with an
MXU-shaped `jnp.dot` replacing the per-PE MAC chain. The grid iteration order
plays the role of the LSGP schedule λ*.

All kernels run `interpret=True`: the CPU PJRT client cannot execute Mosaic
custom-calls, and the AOT artifacts must load in the rust runtime
(/opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, target: int = 8) -> int:
    """Largest divisor of n that is ≤ target (an LSGP-style even tiling)."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1


def matmul(a, b, block: int | None = None):
    """Tiled matmul `A·B` — the GEMM hot-spot.

    Grid (i, j, k) over blocks; the (i, j) output block stays resident while
    k sweeps — exactly the c-accumulation the TCPA keeps in a feedback
    register (paper Fig. 4).
    """
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, "shape mismatch"
    bm = block or _pick_block(n)
    bn = block or _pick_block(m)
    bk = block or _pick_block(k)

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(n // bm, m // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=True,
    )(a, b)


def matvec(a, x, transpose: bool = False, block: int | None = None):
    """Tiled matvec `A·x` (or `Aᵀ·x`) — the ATAX/GESUMMV/MVT hot-spot.

    The vector block is reused across a whole row-block of A — the data
    locality a TCPA exploits by propagating x through the array while CGRAs
    re-load it from the scratchpad every iteration (§IV-6).
    """
    if transpose:
        a = a.T
    n, m = a.shape
    bn = block or _pick_block(n)
    bm = block or _pick_block(m)

    def kernel(a_ref, x_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, x)


def gesummv(a, b, x, block: int | None = None):
    """Fused `A·x + B·x` — one pass over both matrices, two accumulators in
    VMEM (the TCPA's s1/s2 feedback registers)."""
    n, m = a.shape
    bn = block or _pick_block(n)
    bm = block or _pick_block(m)

    def kernel(a_ref, b_ref, x_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        xs = x_ref[...]
        o_ref[...] += jnp.dot(
            a_ref[...], xs, preferred_element_type=o_ref.dtype
        ) + jnp.dot(b_ref[...], xs, preferred_element_type=o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b, x)
